"""Transient thermo-fluid cooling twin: CDUs, facility HX, tower, basin.

Stand-in for the Modelica transient model of Kumar et al. [25] / Greenwood
et al. [22] used by ExaDigiT, grown from the original first-order lumped
model into a small transient plant so Fig. 6-style "what does this schedule
do to the tower loop?" questions — and their weather what-ifs — have real
dynamics behind them. Per engine step ``dt`` (units: W, kg/s, °C, s):

CDU loop, per group g (``kernels.power_topo.cdu_update_ref`` — fused with
the node->group segment reduction on the accelerated path):
  valve      mdot[g]  -> demand q[g]/(cp·ΔT_design), slewed with tau_valve
  pickup     T_ret[g]  = T_sup[g] + q[g]/(mdot[g]·cp)
  supply     T_sup[g] -> max(setpoint, T_basin + q[g]/UA), relaxed w/ tau_hx

Heat reuse (district-heating export): when the flow-weighted return temp is
hot enough to be useful, up to ``reuse_frac`` of the heat (capped at
``reuse_max_w``) is diverted before the tower and never loads it.

Tower + basin:
  staging    s -> (q_tower + basin-error correction)/(cell_ua·(T_b − T_wb)),
              slewed with tau_fan, clipped to [0, n_cells]
  rejection  q_rej = s·cell_ua·(T_basin − T_wb)      (evaporative: wet-bulb
              is the floor — this is where weather enters the twin)
  basin      M·cp·dT_basin/dt = q_tower − q_rej       (thermal mass)

Parasitic power: tower fans follow a staged cube law (whole cells at rated
power + the modulating cell at speed³); CDU pumps follow a cube law on flow
fraction with a 20% base. PUE = (P_IT + P_loss + P_cool) / P_IT, calibrated
so nominal load lands near the paper's note of ~1.06 for the real system.
"""
from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp

from repro.core.types import CoolingState
from repro.kernels.power_topo import ops as topo_ops
from repro.kernels.power_topo.ref import CduParams, cdu_update_ref
from repro.systems.config import CoolingConfig


class CoolingOut(NamedTuple):
    """Per-step cooling telemetry (all f32[] unless noted)."""
    p_cooling: jnp.ndarray      # total cooling parasitics, fans + pumps (W)
    p_fan: jnp.ndarray          # tower fan power (W)
    p_pump: jnp.ndarray         # CDU pump power (W)
    t_tower_return: jnp.ndarray  # flow-weighted water temp at the towers (°C)
    t_basin: jnp.ndarray        # basin temperature after the step (°C)
    t_supply_max: jnp.ndarray   # hottest CDU supply temperature (°C)
    t_return_max: jnp.ndarray   # hottest CDU return temperature (°C)
    q_reuse_w: jnp.ndarray      # heat exported for reuse this step (W)
    q_reject_w: jnp.ndarray     # heat rejected by the tower this step (W)


class ThermalNow(NamedTuple):
    """Cooling-loop pressure signals for the scheduler (traced scalars)."""
    excess: jnp.ndarray      # f32[] how far the hottest return temp sits
    #                          inside the soft band below its limit (0 = cool,
    #                          1 = at the limit; unclipped above)
    overheat: jnp.ndarray    # bool[] supply setpoint lost by more than the
    #                          margin -> admission throttling engages
    t_return_max: jnp.ndarray  # f32[] hottest CDU return temperature (°C)
    t_supply_max: jnp.ndarray  # f32[] hottest CDU supply temperature (°C)


def cdu_params(cfg: CoolingConfig, dt: float) -> CduParams:
    """Static kernel scalars for the per-CDU loop update."""
    return CduParams(
        cp_j_kg_k=cfg.cp_j_kg_k, ua_w_k=cfg.ua_w_k, dt=dt,
        tau_hx_s=cfg.tau_hx_s, tau_valve_s=cfg.tau_valve_s,
        delta_t_design_c=cfg.delta_t_design_c,
        mdot_min_kg_s=cfg.mdot_min_frac * cfg.mdot_kg_s,
        mdot_max_kg_s=cfg.mdot_kg_s)


def init_state(cfg: CoolingConfig) -> CoolingState:
    """Idle-plant initial condition: supply at setpoint, valves at the floor,
    basin at wet-bulb + approach, fans off."""
    g = jnp.full((cfg.n_groups,), cfg.t_supply_setpoint_c, jnp.float32)
    return CoolingState(
        t_supply=g,
        t_return=g + 5.0,
        mdot=jnp.full((cfg.n_groups,), cfg.mdot_min_frac * cfg.mdot_kg_s,
                      jnp.float32),
        t_basin=jnp.float32(cfg.t_wetbulb_c + cfg.tower_approach_c),
        fan_stages=jnp.float32(0.0))


def _effective(cfg: CoolingConfig, t_wetbulb_c, setpoint_delta_c):
    """(ambient wet-bulb, effective supply setpoint) for this step (°C).

    Single source of the two per-step knobs: the wet-bulb defaults to the
    static config when no weather trace drives the run, and the setpoint
    is the config value shifted by the traced ``Scenario.setpoint_delta_c``.
    """
    t_wb = jnp.float32(cfg.t_wetbulb_c) if t_wetbulb_c is None \
        else t_wetbulb_c
    t_set = cfg.t_supply_setpoint_c + jnp.asarray(setpoint_delta_c,
                                                  jnp.float32)
    return t_wb, t_set


def _finish_step(cfg: CoolingConfig, state: CoolingState, dt: float,
                 t_wb, t_set, q, t_return, t_supply, mdot
                 ) -> tuple[CoolingState, CoolingOut]:
    """Tower-side half of the step: reuse split, fan staging, basin mass,
    parasitic power. ``q``/``t_return``/``t_supply``/``mdot`` come from the
    CDU update (plain jnp or the fused kernel); ``t_set`` is the effective
    (setpoint-swept) supply setpoint the basin target follows."""
    q_tot = jnp.sum(q)

    # water temperature arriving at the towers = flow-weighted return temp
    t_ret_mix = jnp.sum(mdot * t_return) / jnp.maximum(jnp.sum(mdot), 1e-6)

    # heat reuse: divert exportable heat from the hot return stream before
    # the tower (only worth it when the water is hot enough to sell)
    q_reuse = jnp.where(t_ret_mix >= cfg.reuse_t_min_c,
                        jnp.minimum(cfg.reuse_frac * q_tot, cfg.reuse_max_w),
                        0.0)
    q_tower = q_tot - q_reuse

    # fan staging: reject the tower-bound heat (minus what the passive path
    # already carries) at the current driving ΔT, plus a proportional
    # correction that steers the basin to its target
    cell_ua = cfg.cell_ua()
    mcp_b = cfg.basin_mcp()
    passive_ua = cfg.passive_ua_frac * cfg.n_tower_cells * cell_ua
    q_passive = passive_ua * (state.t_basin - t_wb)
    t_b_tgt = jnp.maximum(t_wb + cfg.tower_approach_c,
                          t_set - cfg.basin_margin_c)
    drive = jnp.maximum(state.t_basin - t_wb, 0.5)
    q_need = q_tower - q_passive + \
        mcp_b * (state.t_basin - t_b_tgt) / cfg.tower_tau_s
    s_tgt = jnp.clip(q_need / (cell_ua * drive), 0.0,
                     float(cfg.n_tower_cells))
    fan = state.fan_stages + (s_tgt - state.fan_stages) * \
        jnp.clip(dt / cfg.tau_fan_s, 0.0, 1.0)

    # basin thermal mass: heat in from the HX minus tower rejection. The
    # fan path only ever rejects (evaporative, wet-bulb floor); the passive
    # path is bidirectional — a heat wave warms an idle basin
    q_rej = jnp.maximum(fan * cell_ua * (state.t_basin - t_wb), 0.0) + \
        q_passive
    t_basin = state.t_basin + (q_tower - q_rej) * dt / mcp_b

    # parasitics: staged cube-law fans (whole cells at rated power, the
    # modulating cell at speed^3) + cube-law pumps with a 20% base
    k = jnp.floor(fan)
    r = fan - k
    fan_w = cfg.fan_rated_w * (k + r ** 3)
    frac = mdot / cfg.mdot_kg_s
    pump_w = jnp.sum(cfg.pump_w_per_group * (0.2 + 0.8 * frac ** 3))

    new = CoolingState(t_supply=t_supply, t_return=t_return, mdot=mdot,
                       t_basin=t_basin, fan_stages=fan)
    out = CoolingOut(
        p_cooling=fan_w + pump_w, p_fan=fan_w, p_pump=pump_w,
        t_tower_return=t_ret_mix, t_basin=t_basin,
        t_supply_max=jnp.max(t_supply), t_return_max=jnp.max(t_return),
        q_reuse_w=q_reuse, q_reject_w=q_rej)
    return new, out


def step(cfg: CoolingConfig, state: CoolingState, group_heat_w: jnp.ndarray,
         dt: float, t_wetbulb_c=None, setpoint_delta_c=0.0
         ) -> tuple[CoolingState, CoolingOut]:
    """Advance the cooling loop by ``dt`` seconds from per-group heat.

    Args:
      group_heat_w: f32[G] heat load per CDU group (W) — IT power per group,
        already throttled when a power cap is active.
      t_wetbulb_c: ambient wet-bulb (°C, traced); defaults to the static
        ``cfg.t_wetbulb_c`` when no weather trace drives the run.
      setpoint_delta_c: offset on the supply setpoint (°C, traced) — the
        ``Scenario.setpoint_delta_c`` sweep knob.
    Returns:
      (new_state, CoolingOut telemetry).
    """
    t_wb, t_set = _effective(cfg, t_wetbulb_c, setpoint_delta_c)
    q, t_return, t_supply, mdot = cdu_update_ref(
        group_heat_w, state.t_supply, state.mdot, state.t_basin, t_set,
        cdu_params(cfg, dt))
    return _finish_step(cfg, state, dt, t_wb, t_set, q, t_return, t_supply,
                        mdot)


def step_from_node_power(cfg: CoolingConfig, state: CoolingState,
                         node_pw: jnp.ndarray, dt: float,
                         t_wetbulb_c=None, setpoint_delta_c=0.0,
                         use_pallas: bool = False
                         ) -> tuple[CoolingState, CoolingOut, jnp.ndarray]:
    """Like ``step`` but fused: the node->CDU segment reduction and the CDU
    loop update run as one pass (``kernels.power_topo.fused_cooling``), and
    total IT power falls out of the group sums for free.

    Returns:
      (new_state, CoolingOut, p_it) with ``p_it`` = f32[] total IT power (W).
    """
    t_wb, t_set = _effective(cfg, t_wetbulb_c, setpoint_delta_c)
    q, t_return, t_supply, mdot = topo_ops.fused_cooling(
        node_pw, state.t_supply, state.mdot, state.t_basin,
        jnp.broadcast_to(t_set, state.t_basin.shape), cfg.n_groups,
        cdu_params(cfg, dt), use_pallas=use_pallas)
    new, out = _finish_step(cfg, state, dt, t_wb, t_set, q, t_return,
                            t_supply, mdot)
    return new, out, jnp.sum(q)


def thermal_now(cfg: CoolingConfig, state: CoolingState,
                setpoint_delta_c=0.0) -> ThermalNow:
    """Cooling-pressure signals for the scheduler, from the current state.

    ``excess`` ramps 0 -> 1 across the soft band
    [t_return_limit_c - thermal_margin_c, t_return_limit_c]; the
    thermal_aware policy multiplies it into its heat-dense-job penalty.
    ``overheat`` trips when the hottest CDU supply exceeds the (effective)
    setpoint by ``t_supply_margin_c`` — cooling has lost setpoint control,
    so admission throttles until it recovers.
    """
    t_ret = jnp.max(state.t_return)
    t_sup = jnp.max(state.t_supply)
    soft = cfg.t_return_limit_c - cfg.thermal_margin_c
    excess = jnp.maximum(t_ret - soft, 0.0) / cfg.thermal_margin_c
    _, t_set = _effective(cfg, None, setpoint_delta_c)
    overheat = t_sup > t_set + cfg.t_supply_margin_c
    return ThermalNow(excess=excess, overheat=overheat, t_return_max=t_ret,
                      t_supply_max=t_sup)


def thermal_neutral() -> ThermalNow:
    """Signals that make every cooling-aware term a no-op."""
    z = jnp.float32(0.0)
    return ThermalNow(excess=z, overheat=jnp.bool_(False), t_return_max=z,
                      t_supply_max=z)


def pue(p_it: jnp.ndarray, p_loss: jnp.ndarray,
        p_cooling: jnp.ndarray) -> jnp.ndarray:
    """Power usage effectiveness: facility input power over IT power (W/W)."""
    return (p_it + p_loss + p_cooling) / jnp.maximum(p_it, 1.0)
