"""Lumped-parameter thermo-fluid cooling model.

Stand-in for the Modelica transient model of Kumar et al. [25] / Greenwood et
al. [22] used by ExaDigiT. We keep the quantities the paper plots — PUE and
the water temperature arriving at the cooling towers (Fig. 6) — and their
qualitative response to scheduling-induced load swings, using a lumped model:

  per CDU group g (heat pickup):
      T_return[g] = T_supply[g] + Q[g] / (mdot * cp)
  facility loop (first-order approach to the tower basin temperature):
      dT_supply[g]/dt = (T_mix - T_supply[g]) / tau_hx,
      T_mix = T_tower + Q[g]/UA          (HX effectiveness folded into UA)
  tower (first-order lag toward wet-bulb + approach, loaded by total heat):
      T_target = T_wb + approach + Q_tot / (UA_tower)
      dT_tower/dt = (T_target - T_tower) / tau_tower
  fan power: cube-law on required heat-rejection fraction.

PUE = (P_IT + P_loss + P_cooling) / P_IT, matching the paper's note that PUE
for the real system averages ~1.06.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.types import CoolingState
from repro.systems.config import CoolingConfig


def init_state(cfg: CoolingConfig) -> CoolingState:
    g = jnp.full((cfg.n_groups,), cfg.t_supply_setpoint_c, jnp.float32)
    return CoolingState(
        t_supply=g,
        t_return=g + 5.0,
        t_tower=jnp.float32(cfg.t_wetbulb_c + cfg.tower_approach_c),
    )


def step(cfg: CoolingConfig, state: CoolingState, group_heat_w: jnp.ndarray,
         dt: float) -> tuple[CoolingState, jnp.ndarray, jnp.ndarray]:
    """Advance the cooling loop by ``dt`` seconds.

    Args:
      group_heat_w: f32[G] heat load per CDU group (== IT power per group).
    Returns:
      (new_state, cooling_power_w, tower_return_temp_c)
    """
    q = group_heat_w
    q_tot = jnp.sum(q)

    # CDU heat pickup
    mcp = cfg.mdot_kg_s * cfg.cp_j_kg_k
    t_return = state.t_supply + q / mcp

    # facility loop: supply relaxes toward tower temp + HX penalty
    t_mix = state.t_tower + q / cfg.ua_w_k
    tau_hx = 120.0
    t_supply = state.t_supply + (t_mix - state.t_supply) * (dt / tau_hx)

    # tower: loaded equilibrium + first-order lag
    ua_tower = cfg.ua_w_k * cfg.n_groups
    t_target = cfg.t_wetbulb_c + cfg.tower_approach_c + q_tot / ua_tower
    alpha = dt / cfg.tower_tau_s
    t_tower = state.t_tower + (t_target - state.t_tower) * jnp.clip(alpha, 0.0, 1.0)

    # water temperature arriving at the towers = flow-weighted return temp
    t_tower_return = jnp.mean(t_return)

    # parasitic power: tower fans (cube law on load fraction) + CDU pumps
    q_rated = cfg.n_tower_cells * cfg.cell_rated_heat_w
    frac = jnp.clip(q_tot / q_rated, 0.0, 1.2)
    fan_w = cfg.n_tower_cells * cfg.fan_rated_w * frac ** 3
    pump_w = cfg.n_groups * cfg.pump_w_per_group
    cooling_w = fan_w + pump_w

    return CoolingState(t_supply=t_supply, t_return=t_return,
                        t_tower=t_tower), cooling_w, t_tower_return


def pue(p_it: jnp.ndarray, p_loss: jnp.ndarray,
        p_cooling: jnp.ndarray) -> jnp.ndarray:
    return (p_it + p_loss + p_cooling) / jnp.maximum(p_it, 1.0)
