"""Transient thermo-fluid cooling twin: CDUs, facility HX, towers, basins —
hierarchical: halls -> CDU groups -> nodes.

Stand-in for the Modelica transient model of Kumar et al. [25] / Greenwood
et al. [22] used by ExaDigiT, grown from the original first-order lumped
model into a small transient plant so Fig. 6-style "what does this schedule
do to the tower loop?" questions — and their weather and maintenance
what-ifs — have real dynamics behind them. The plant is a
``FacilityTopology`` (repro.systems.config): each *hall* owns a tower loop
(basin + fan cells) serving its contiguous span of CDU groups, with its
own ambient wet-bulb (per-hall weather traces) and its own maintenance
state (``cells_offline``). A flat plant is the one-hall special case and
reproduces the pre-hierarchy behavior exactly. Per engine step ``dt``
(units: W, kg/s, °C, s):

CDU loop, per group g (``kernels.power_topo.cdu_update_ref`` — fused with
the node->group segment reduction on the accelerated path; each group
relaxes against its *hall's* basin):
  valve      mdot[g]  -> demand q[g]/(cp·ΔT_design), slewed with tau_valve
  pickup     T_ret[g]  = T_sup[g] + q[g]/(mdot[g]·cp)
  supply     T_sup[g] -> max(setpoint, T_basin[hall(g)] + q[g]/UA),
             relaxed w/ tau_hx

Heat reuse (district-heating export), per hall: when the hall's
flow-weighted return temp is hot enough to be useful, up to ``reuse_frac``
of that hall's heat (capped at its share of ``reuse_max_w``) is diverted
before the tower and never loads it.

Tower + basin, per hall h:
  staging    s[h] -> (q_tower[h] + basin-error correction)/(cell_ua·ΔT),
              slewed with tau_fan, clipped to [0, cells online in h] —
              ``cells_offline`` (maintenance) shrinks the ceiling
  rejection  q_rej[h] = s[h]·cell_ua·(T_basin[h] − T_wb[h])  (evaporative:
              the hall's wet-bulb is the floor — per-hall weather enters
              the twin here)
  basin      M[h]·cp·dT_basin[h]/dt = q_tower[h] − q_rej[h]

Parasitic power: tower fans follow a staged cube law per hall (whole cells
at rated power + the modulating cell at speed³); CDU pumps follow a cube
law on flow fraction with a 20% base. PUE = (P_IT + P_loss + P_cool) /
P_IT, calibrated so nominal load lands near the paper's note of ~1.06 for
the real system.
"""
from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp

from repro.core.types import CoolingState
from repro.kernels.power_topo import ops as topo_ops
from repro.kernels.power_topo.ref import (CduParams, cdu_update_ref,
                                          hall_matrix, hall_max_ref)
from repro.systems.config import CoolingConfig


class CoolingOut(NamedTuple):
    """Per-step cooling telemetry. Scalars are facility aggregates (max /
    flow-weighted mix / sum over halls — identical to the flat-plant
    values when H = 1); ``*_hall`` fields carry the per-hall view
    (f32[H])."""
    p_cooling: jnp.ndarray      # total cooling parasitics, fans + pumps (W)
    p_fan: jnp.ndarray          # tower fan power (W)
    p_pump: jnp.ndarray         # CDU pump power (W)
    t_tower_return: jnp.ndarray  # flow-weighted water temp at the towers (°C)
    t_basin: jnp.ndarray        # hottest basin temperature after the step (°C)
    t_supply_max: jnp.ndarray   # hottest CDU supply temperature (°C)
    t_return_max: jnp.ndarray   # hottest CDU return temperature (°C)
    q_reuse_w: jnp.ndarray      # heat exported for reuse this step (W)
    q_reject_w: jnp.ndarray     # heat rejected by the towers this step (W)
    # per-hall telemetry (H = FacilityTopology.n_halls)
    q_hall_w: jnp.ndarray          # f32[H] heat landing in each hall (W)
    t_basin_hall: jnp.ndarray      # f32[H] basin temperature per hall (°C)
    t_supply_max_hall: jnp.ndarray  # f32[H] hottest CDU supply per hall (°C)
    t_return_max_hall: jnp.ndarray  # f32[H] hottest CDU return per hall (°C)
    q_reject_hall_w: jnp.ndarray   # f32[H] tower rejection per hall (W)
    fan_w_hall: jnp.ndarray        # f32[H] fan power per hall (W)
    cells_online: jnp.ndarray      # f32[H] tower cells available per hall
    t_wetbulb_hall: jnp.ndarray    # f32[H] ambient wet-bulb per hall (°C)


class ThermalNow(NamedTuple):
    """Cooling-pressure signals for the scheduler. Scalars aggregate over
    halls (max / any) — the flat-plant semantics; the ``*_hall`` arrays
    let the hall-aware placement and admission gate target (only) the
    overheating hall."""
    excess: jnp.ndarray      # f32[] how far the hottest return temp sits
    #                          inside the soft band below its limit (0 = cool,
    #                          1 = at the limit; unclipped above)
    overheat: jnp.ndarray    # bool[] supply setpoint lost by more than the
    #                          margin in SOME hall -> admission throttling
    t_return_max: jnp.ndarray  # f32[] hottest CDU return temperature (°C)
    t_supply_max: jnp.ndarray  # f32[] hottest CDU supply temperature (°C)
    excess_hall: jnp.ndarray   # f32[H] per-hall soft-band excess
    overheat_hall: jnp.ndarray  # bool[H] per-hall setpoint-lost flag


def cdu_params(cfg: CoolingConfig, dt: float) -> CduParams:
    """Static kernel scalars for the per-CDU loop update."""
    return CduParams(
        cp_j_kg_k=cfg.cp_j_kg_k, ua_w_k=cfg.ua_w_k, dt=dt,
        tau_hx_s=cfg.tau_hx_s, tau_valve_s=cfg.tau_valve_s,
        delta_t_design_c=cfg.delta_t_design_c,
        mdot_min_kg_s=cfg.mdot_min_frac * cfg.mdot_kg_s,
        mdot_max_kg_s=cfg.mdot_kg_s)


class _Halls(NamedTuple):
    """Static per-hall constants, materialized once per trace from the
    ``FacilityTopology`` (all f32[H] / f32[G] / f32[G, H] jnp constants)."""
    hog: jnp.ndarray        # i32[G] hall of each CDU group
    hmat: jnp.ndarray       # f32[G, H] one-hot group->hall matrix
    cells: jnp.ndarray      # f32[H] installed tower cells
    mcp: jnp.ndarray        # f32[H] basin thermal mass x cp (J/K)
    passive_ua: jnp.ndarray  # f32[H] fans-off ambient coupling (W/K)
    reuse_max: jnp.ndarray  # f32[H] heat-export capacity share (W)


def halls(cfg: CoolingConfig) -> _Halls:
    """Resolve the static topology into per-hall jnp constants."""
    hog_t = cfg.hall_of_group()
    H = cfg.n_halls
    cells = jnp.asarray(cfg.cells_per_hall(), jnp.float32)
    cell_ua = cfg.cell_ua()
    return _Halls(
        hog=jnp.asarray(hog_t, jnp.int32),
        hmat=hall_matrix(hog_t, H),
        cells=cells,
        mcp=jnp.asarray(cfg.basin_mcp_per_hall(), jnp.float32),
        passive_ua=cfg.passive_ua_frac * cells * cell_ua,
        reuse_max=cfg.reuse_max_w * jnp.asarray(cfg.hall_weights(),
                                                jnp.float32))


def init_state(cfg: CoolingConfig) -> CoolingState:
    """Idle-plant initial condition: supply at setpoint, valves at the floor,
    every hall's basin at wet-bulb + approach, fans off."""
    g = jnp.full((cfg.n_groups,), cfg.t_supply_setpoint_c, jnp.float32)
    H = cfg.n_halls
    return CoolingState(
        t_supply=g,
        t_return=g + 5.0,
        mdot=jnp.full((cfg.n_groups,), cfg.mdot_min_frac * cfg.mdot_kg_s,
                      jnp.float32),
        t_basin=jnp.full((H,), cfg.t_wetbulb_c + cfg.tower_approach_c,
                         jnp.float32),
        fan_stages=jnp.zeros((H,), jnp.float32))


def _effective(cfg: CoolingConfig, t_wetbulb_c, setpoint_delta_c):
    """(per-hall ambient wet-bulb f32[H], effective supply setpoint f32[])
    for this step (°C).

    Single source of the two per-step knobs: the wet-bulb defaults to the
    static config when no weather trace drives the run and broadcasts a
    shared trace across halls (a per-hall trace arrives as f32[H], see
    ``repro.cooling.weather.stack_halls``); the setpoint is the config
    value shifted by the traced ``Scenario.setpoint_delta_c``.
    """
    t_wb = jnp.float32(cfg.t_wetbulb_c) if t_wetbulb_c is None \
        else jnp.asarray(t_wetbulb_c, jnp.float32)
    t_wb = jnp.broadcast_to(t_wb, (cfg.n_halls,))
    t_set = cfg.t_supply_setpoint_c + jnp.asarray(setpoint_delta_c,
                                                  jnp.float32)
    return t_wb, t_set


def _finish_step(cfg: CoolingConfig, state: CoolingState, dt: float,
                 t_wb, t_set, q, t_return, t_supply, mdot,
                 cells_offline=0.0, cells_failed=0.0, q_hall=None
                 ) -> tuple[CoolingState, CoolingOut]:
    """Tower-side half of the step, vectorized over halls: reuse split, fan
    staging, basin mass, parasitic power. ``q``/``t_return``/``t_supply``/
    ``mdot`` come from the CDU update (plain jnp or the fused kernel);
    ``t_wb`` is the per-hall wet-bulb f32[H]; ``t_set`` the effective
    (setpoint-swept) supply setpoint the basin targets follow;
    ``cells_offline`` the traced maintenance knob (scalar or f32[H]);
    ``cells_failed`` the stochastic-failure cell count from the event
    layer (scalar or f32[H]) — unlike planned maintenance, a *failed*
    cell also loses its passive windage coupling (seized fan, closed
    dampers), so it derates ``passive_ua`` proportionally;
    ``q_hall`` the per-hall heat sums when the caller already reduced
    them (the hierarchical fused kernel) — recomputed here otherwise."""
    hs = halls(cfg)
    if q_hall is None:
        q_hall = q @ hs.hmat

    # water temperature arriving at each hall's towers = the hall's
    # flow-weighted return temp; the facility scalar mixes all groups
    mdot_hall = mdot @ hs.hmat
    t_ret_mix_hall = (mdot * t_return) @ hs.hmat / \
        jnp.maximum(mdot_hall, 1e-6)
    t_ret_mix = jnp.sum(mdot * t_return) / jnp.maximum(jnp.sum(mdot), 1e-6)

    # heat reuse, per hall: divert exportable heat from the hot return
    # stream before the tower (only worth it when the water is hot enough
    # to sell). The export capacity split is each hall's *static*
    # CDU-count share (hall_weights) — district-heating tie-ins are
    # plumbed per hall, so capacity stranded in a load-shedding hall does
    # not migrate to the loaded one
    q_reuse_h = jnp.where(t_ret_mix_hall >= cfg.reuse_t_min_c,
                          jnp.minimum(cfg.reuse_frac * q_hall, hs.reuse_max),
                          0.0)
    q_tower_h = q_hall - q_reuse_h

    # fan staging, per hall: reject the tower-bound heat (minus what the
    # passive path already carries) at the current driving ΔT, plus a
    # proportional correction that steers the basin to its target. Offline
    # cells (maintenance) cap the staging ceiling — the basin mass and the
    # passive (windage) path are installed hardware and stay
    cell_ua = cfg.cell_ua()
    passive_ua = hs.passive_ua
    off = jnp.asarray(cells_offline, jnp.float32)
    if not (isinstance(cells_failed, (int, float)) and cells_failed == 0.0):
        # stochastic failures stack on top of maintenance and, unlike
        # maintenance, take the failed cells' windage path down with them
        cf = jnp.clip(jnp.asarray(cells_failed, jnp.float32), 0.0, hs.cells)
        off = off + cf
        passive_ua = hs.passive_ua * (1.0 - cf / hs.cells)
    cells_on = jnp.clip(hs.cells - off, 0.0, hs.cells)
    q_passive = passive_ua * (state.t_basin - t_wb)
    t_b_tgt = jnp.maximum(t_wb + cfg.tower_approach_c,
                          t_set - cfg.basin_margin_c)
    drive = jnp.maximum(state.t_basin - t_wb, 0.5)
    q_need = q_tower_h - q_passive + \
        hs.mcp * (state.t_basin - t_b_tgt) / cfg.tower_tau_s
    s_tgt = jnp.clip(q_need / (cell_ua * drive), 0.0, cells_on)
    fan = state.fan_stages + (s_tgt - state.fan_stages) * \
        jnp.clip(dt / cfg.tau_fan_s, 0.0, 1.0)
    # a cell pulled offline mid-run also drops out of the *current*
    # staging state, not just the target
    fan = jnp.minimum(fan, cells_on)

    # basin thermal mass, per hall: heat in from the HX minus tower
    # rejection. The fan path only ever rejects (evaporative, wet-bulb
    # floor); the passive path is bidirectional — a heat wave warms an
    # idle basin
    q_rej = jnp.maximum(fan * cell_ua * (state.t_basin - t_wb), 0.0) + \
        q_passive
    t_basin = state.t_basin + (q_tower_h - q_rej) * dt / hs.mcp

    # parasitics: staged cube-law fans per hall (whole cells at rated
    # power, the modulating cell at speed^3) + cube-law pumps with a 20%
    # base
    k = jnp.floor(fan)
    r = fan - k
    fan_w_h = cfg.fan_rated_w * (k + r ** 3)
    fan_w = jnp.sum(fan_w_h)
    frac = mdot / cfg.mdot_kg_s
    pump_w = jnp.sum(cfg.pump_w_per_group * (0.2 + 0.8 * frac ** 3))

    new = CoolingState(t_supply=t_supply, t_return=t_return, mdot=mdot,
                       t_basin=t_basin, fan_stages=fan)
    out = CoolingOut(
        p_cooling=fan_w + pump_w, p_fan=fan_w, p_pump=pump_w,
        t_tower_return=t_ret_mix, t_basin=jnp.max(t_basin),
        t_supply_max=jnp.max(t_supply), t_return_max=jnp.max(t_return),
        q_reuse_w=jnp.sum(q_reuse_h), q_reject_w=jnp.sum(q_rej),
        q_hall_w=q_hall, t_basin_hall=t_basin,
        t_supply_max_hall=hall_max_ref(t_supply, hs.hog, cfg.n_halls),
        t_return_max_hall=hall_max_ref(t_return, hs.hog, cfg.n_halls),
        q_reject_hall_w=q_rej, fan_w_hall=fan_w_h, cells_online=cells_on,
        t_wetbulb_hall=t_wb)
    return new, out


def step(cfg: CoolingConfig, state: CoolingState, group_heat_w: jnp.ndarray,
         dt: float, t_wetbulb_c=None, setpoint_delta_c=0.0,
         cells_offline=0.0, cells_failed=0.0
         ) -> tuple[CoolingState, CoolingOut]:
    """Advance the cooling plant by ``dt`` seconds from per-group heat.

    Args:
      group_heat_w: f32[G] heat load per CDU group (W) — IT power per group,
        already throttled when a power cap is active.
      t_wetbulb_c: ambient wet-bulb (°C, traced); scalar (shared) or
        f32[H] (per-hall weather); defaults to the static
        ``cfg.t_wetbulb_c`` when no weather trace drives the run.
      setpoint_delta_c: offset on the supply setpoint (°C, traced) — the
        ``Scenario.setpoint_delta_c`` sweep knob.
      cells_offline: tower cells out for maintenance (traced; scalar or
        f32[H]) — the ``Scenario.cells_offline`` what-if knob.
      cells_failed: tower cells down from stochastic failures (traced;
        scalar or f32[H]) — fed by ``repro.events``; also derates the
        passive windage path.
    Returns:
      (new_state, CoolingOut telemetry).
    """
    t_wb, t_set = _effective(cfg, t_wetbulb_c, setpoint_delta_c)
    hs = halls(cfg)
    t_basin_g = state.t_basin[hs.hog]   # each group sees its hall's basin
    q, t_return, t_supply, mdot = cdu_update_ref(
        group_heat_w, state.t_supply, state.mdot, t_basin_g,
        jnp.broadcast_to(t_set, t_basin_g.shape), cdu_params(cfg, dt))
    return _finish_step(cfg, state, dt, t_wb, t_set, q, t_return, t_supply,
                        mdot, cells_offline, cells_failed)


def step_from_node_power(cfg: CoolingConfig, state: CoolingState,
                         node_pw: jnp.ndarray, dt: float,
                         t_wetbulb_c=None, setpoint_delta_c=0.0,
                         cells_offline=0.0, cells_failed=0.0,
                         use_pallas: bool = False
                         ) -> tuple[CoolingState, CoolingOut, jnp.ndarray]:
    """Like ``step`` but fused: the node->CDU->hall segment reduction and
    the CDU loop update run as one pass
    (``kernels.power_topo.fused_cooling_hier``), and total IT power falls
    out of the hall sums for free.

    Returns:
      (new_state, CoolingOut, p_it) with ``p_it`` = f32[] total IT power (W).
    """
    t_wb, t_set = _effective(cfg, t_wetbulb_c, setpoint_delta_c)
    q, t_return, t_supply, mdot, q_hall = topo_ops.fused_cooling_hier(
        node_pw, state.t_supply, state.mdot, state.t_basin, t_set,
        cfg.hall_of_group(), cfg.n_groups, cdu_params(cfg, dt),
        use_pallas=use_pallas)
    new, out = _finish_step(cfg, state, dt, t_wb, t_set, q, t_return,
                            t_supply, mdot, cells_offline, cells_failed,
                            q_hall=q_hall)
    return new, out, jnp.sum(q_hall)


def thermal_now(cfg: CoolingConfig, state: CoolingState,
                setpoint_delta_c=0.0) -> ThermalNow:
    """Cooling-pressure signals for the scheduler, from the current state.

    ``excess`` ramps 0 -> 1 across the soft band
    [t_return_limit_c - thermal_margin_c, t_return_limit_c]; the
    thermal_aware policy multiplies it into its heat-dense-job penalty.
    ``overheat`` trips when a hall's hottest CDU supply exceeds the
    (effective) setpoint by ``t_supply_margin_c`` — that hall has lost
    setpoint control, so admission into it throttles until it recovers
    (the scalar aggregates keep the flat-plant semantics: max / any).
    """
    hs = halls(cfg)
    t_ret_h = hall_max_ref(state.t_return, hs.hog, cfg.n_halls)
    t_sup_h = hall_max_ref(state.t_supply, hs.hog, cfg.n_halls)
    soft = cfg.t_return_limit_c - cfg.thermal_margin_c
    excess_h = jnp.maximum(t_ret_h - soft, 0.0) / cfg.thermal_margin_c
    _, t_set = _effective(cfg, None, setpoint_delta_c)
    overheat_h = t_sup_h > t_set + cfg.t_supply_margin_c
    return ThermalNow(excess=jnp.max(excess_h),
                      overheat=jnp.any(overheat_h),
                      t_return_max=jnp.max(t_ret_h),
                      t_supply_max=jnp.max(t_sup_h),
                      excess_hall=excess_h, overheat_hall=overheat_h)


def thermal_neutral(n_halls: int = 1) -> ThermalNow:
    """Signals that make every cooling-aware term a no-op."""
    z = jnp.float32(0.0)
    return ThermalNow(excess=z, overheat=jnp.bool_(False), t_return_max=z,
                      t_supply_max=z,
                      excess_hall=jnp.zeros((n_halls,), jnp.float32),
                      overheat_hall=jnp.zeros((n_halls,), jnp.bool_))


def pue(p_it: jnp.ndarray, p_loss: jnp.ndarray,
        p_cooling: jnp.ndarray) -> jnp.ndarray:
    """Power usage effectiveness: facility input power over IT power (W/W)."""
    return (p_it + p_loss + p_cooling) / jnp.maximum(p_it, 1.0)
