"""Weather traces for cooling what-ifs: per-step ambient conditions.

The transient cooling twin (repro.cooling.model) is driven by the ambient
wet-bulb temperature — the floor an evaporative tower can cool against —
so "what does a heat wave do to the tower loop?" becomes a simulation
input, exactly like the grid layer's carbon/price/cap signals
(repro.grid.signals): weather is host-precomputed into per-step arrays
sampled at the engine ``dt``, and the compiled engine only ever *gathers*
the row at the current step (clamped, LOCF-style). One ``WeatherSignals``
set is shared by broadcast across a vmapped scenario sweep; a sweep over
weather *scenarios* stacks several sets on the batch axis
(``stack_weather`` / ``engine.simulate_sweep(weather=[...])``).

Units: all temperatures are °C; times are seconds.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import NamedTuple, Sequence

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.types import _register


@_register
@dataclass
class WeatherSignals:
    """Per-step ambient conditions. Shapes: f32[S] (S = engine steps) for a
    site-wide trace, or f32[S, H] for one trace per hall
    (``stack_halls``) — machine halls a few hundred meters apart share
    weather, but per-hall traces express microclimate what-ifs (a hall
    whose towers sit on the sun-side roof) and, more importantly, give
    each hall's evaporative floor its own knob in maintenance studies."""
    t_wetbulb_c: jnp.ndarray   # ambient wet-bulb temperature (°C)
    t_drybulb_c: jnp.ndarray   # ambient dry-bulb temperature (°C)

    @property
    def num_steps(self) -> int:
        return self.t_wetbulb_c.shape[0]


class WeatherNow(NamedTuple):
    """The ambient conditions active at one engine step (traced): scalars
    for a site-wide trace, f32[H] when the trace is stacked per hall."""
    t_wetbulb_c: jnp.ndarray   # f32[] / f32[H] °C
    t_drybulb_c: jnp.ndarray   # f32[] / f32[H] °C


def at_step(weather: WeatherSignals, step: jnp.ndarray) -> WeatherNow:
    """Gather the weather row active at ``step`` (index clamped into range,
    matching the LOCF profile semantics of paper §3.2.2)."""
    i = jnp.clip(step, 0, weather.num_steps - 1)
    return WeatherNow(t_wetbulb_c=weather.t_wetbulb_c[i],
                      t_drybulb_c=weather.t_drybulb_c[i])


def constant_weather(n_steps: int, t_wetbulb_c: float,
                     t_drybulb_c: float | None = None) -> WeatherSignals:
    """Flat ambient conditions (the pre-weather engine behavior, made
    explicit). ``t_drybulb_c`` defaults to wet-bulb + 8 °C depression."""
    if t_drybulb_c is None:
        t_drybulb_c = t_wetbulb_c + 8.0
    full = lambda v: jnp.full((max(n_steps, 1),), v, jnp.float32)
    return WeatherSignals(t_wetbulb_c=full(t_wetbulb_c),
                          t_drybulb_c=full(t_drybulb_c))


def from_arrays(t_wetbulb_c: np.ndarray,
                t_drybulb_c: np.ndarray | None = None) -> WeatherSignals:
    """Loader hook: wrap measured per-step temperature arrays (°C).

    This is the bridge for real meteorological traces (e.g. hourly METAR /
    ERA5 rows resampled to the engine ``dt`` on the host): the engine does
    not care where the arrays came from, only that they are sampled at
    ``SystemConfig.dt``. Dry-bulb defaults to wet-bulb + 8 °C.
    """
    wb = np.asarray(t_wetbulb_c, np.float32)
    db = (wb + 8.0 if t_drybulb_c is None
          else np.asarray(t_drybulb_c, np.float32))
    if db.shape != wb.shape:
        raise ValueError(f"shape mismatch: {wb.shape} vs {db.shape}")
    return WeatherSignals(t_wetbulb_c=jnp.asarray(wb), t_drybulb_c=jnp.asarray(db))


def synthetic_weather(n_steps: int, dt: float, t0: float = 0.0,
                      t_wb_mean_c: float = 18.0,
                      diurnal_amp_c: float = 4.0,
                      seasonal_amp_c: float = 6.0,
                      day_of_year: float = 172.0,
                      depression_c: float = 8.0,
                      noise_c: float = 0.5,
                      seed: int = 0) -> WeatherSignals:
    """Synthetic diurnal + seasonal wet-bulb/dry-bulb generator.

    Wet-bulb = annual mean + seasonal sinusoid (peaking at midsummer,
    ``day_of_year`` selects where in the year the window sits) + diurnal
    sinusoid (trough ~05:00, peak ~15:00) + AR(1) weather noise. Dry-bulb
    adds a wet-bulb depression that widens in the afternoon (drier air when
    it is hottest).

    Args:
      n_steps: number of engine steps to generate.
      dt: engine step (s).
      t0: simulation start time (s) — sets the diurnal phase.
      t_wb_mean_c: annual-mean wet-bulb (°C).
      diurnal_amp_c / seasonal_amp_c: sinusoid amplitudes (°C).
      day_of_year: where the window starts in the seasonal cycle (days).
      depression_c: mean dry-bulb minus wet-bulb (°C).
      noise_c: AR(1) noise standard deviation (°C).
      seed: RNG seed for the noise.
    Returns:
      ``WeatherSignals`` with f32[n_steps] arrays.
    """
    rng = np.random.default_rng(seed)
    t = t0 + dt * np.arange(n_steps, dtype=np.float64)
    day = 2 * np.pi * t / 86400.0
    season = 2 * np.pi * (day_of_year + t / 86400.0) / 365.0

    e = rng.normal(0.0, noise_c, n_steps)
    noise = np.empty(n_steps)
    acc, rho = 0.0, 0.995
    for i in range(n_steps):
        acc = rho * acc + np.sqrt(1 - rho * rho) * e[i]
        noise[i] = acc

    # diurnal trough ~05:00, peak ~15:00; seasonal peak at midsummer (~day 172)
    diurnal = np.sin(day - 2 * np.pi * 10.0 / 24.0)
    seasonal = np.cos(season - 2 * np.pi * 172.0 / 365.0)
    wb = t_wb_mean_c + seasonal_amp_c * seasonal + diurnal_amp_c * diurnal \
        + noise
    # afternoon air is drier: depression widens with the diurnal phase
    db = wb + depression_c * (1.0 + 0.35 * diurnal)
    return WeatherSignals(t_wetbulb_c=jnp.asarray(wb, jnp.float32),
                          t_drybulb_c=jnp.asarray(db, jnp.float32))


def heat_wave(base: WeatherSignals, dt: float, start_s: float,
              duration_s: float, peak_amp_c: float = 8.0) -> WeatherSignals:
    """Overlay a heat-wave bump on an existing trace.

    The bump is a smooth plateau (cosine ramp up / down over the first and
    last 20% of ``duration_s``) of ``peak_amp_c`` °C added to both wet-bulb
    and dry-bulb — the "what if the schedule meets a 3-day heat wave?"
    scenario input.
    """
    n = base.num_steps
    t = dt * np.arange(n, dtype=np.float64)
    x = (t - start_s) / max(duration_s, 1.0)   # 0..1 inside the wave
    ramp = 0.2
    up = 0.5 * (1 - np.cos(np.pi * np.clip(x / ramp, 0.0, 1.0)))
    down = 0.5 * (1 - np.cos(np.pi * np.clip((1.0 - x) / ramp, 0.0, 1.0)))
    bump = np.where((x >= 0.0) & (x <= 1.0),
                    peak_amp_c * np.minimum(up, down), 0.0).astype(np.float32)
    return WeatherSignals(
        t_wetbulb_c=base.t_wetbulb_c + jnp.asarray(bump),
        t_drybulb_c=base.t_drybulb_c + jnp.asarray(bump))


def stack_weather(traces: Sequence[WeatherSignals]) -> WeatherSignals:
    """Stack weather scenarios on a leading batch axis for vmapped sweeps
    (each scenario row then sees its own trace; see engine.simulate_sweep)."""
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *traces)


def stack_halls(traces: Sequence[WeatherSignals]) -> WeatherSignals:
    """Stack one trace per *hall* on a trailing axis: f32[S] -> f32[S, H].

    The engine's per-step gather (``at_step``) then yields f32[H] rows
    that broadcast against the per-hall basin state — each hall's tower
    sees its own wet-bulb. Composes with ``stack_weather``: build the
    per-hall set for each scenario first, then stack scenarios on the
    leading (vmap) axis, e.g.
    ``simulate_sweep(weather=[stack_halls(ws) for ws in per_scenario])``.
    """
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs, axis=-1),
                                  *traces)
