"""Transient cooling twin: weather-driven CDU + tower loop model.

``weather`` -- per-step ambient wet-bulb/dry-bulb traces + in-scan indexing
               (synthetic diurnal+seasonal generators, heat-wave overlay,
               measured-trace loader hook).
``model``   -- the transient plant, hierarchical (halls -> CDU groups ->
               nodes, ``FacilityTopology``): per-CDU valve/pump dynamics,
               facility HX, per-hall tower fan staging with cube-law power,
               per-hall basin thermal mass, maintenance (cells offline) and
               a heat-reuse/export side stream.
"""
from repro.cooling.weather import (  # noqa: F401
    WeatherNow, WeatherSignals, at_step, constant_weather, from_arrays,
    heat_wave, stack_halls, stack_weather, synthetic_weather)
from repro.cooling.model import (  # noqa: F401
    CoolingOut, ThermalNow, halls, init_state, pue, step,
    step_from_node_power, thermal_neutral, thermal_now)
