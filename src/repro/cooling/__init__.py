"""Transient cooling twin: weather-driven CDU + tower loop model.

``weather`` -- per-step ambient wet-bulb/dry-bulb traces + in-scan indexing
               (synthetic diurnal+seasonal generators, heat-wave overlay,
               measured-trace loader hook).
``model``   -- the transient loop: per-CDU valve/pump dynamics, facility HX,
               tower fan staging with cube-law power, basin thermal mass and
               a heat-reuse/export side stream.
"""
from repro.cooling.weather import (  # noqa: F401
    WeatherNow, WeatherSignals, at_step, constant_weather, from_arrays,
    heat_wave, stack_weather, synthetic_weather)
from repro.cooling.model import (  # noqa: F401
    CoolingOut, ThermalNow, init_state, pue, step, step_from_node_power,
    thermal_neutral, thermal_now)
