"""Arch config: qwen2.5-3b (see repro.configs.registry for exact dims)."""
from repro.configs.registry import get_config

CONFIG = get_config("qwen2.5-3b")
SMOKE = get_config("qwen2.5-3b-smoke")
