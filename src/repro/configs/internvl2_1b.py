"""Arch config: internvl2-1b (see repro.configs.registry for exact dims)."""
from repro.configs.registry import get_config

CONFIG = get_config("internvl2-1b")
SMOKE = get_config("internvl2-1b-smoke")
