"""Arch config: mistral-nemo-12b (see repro.configs.registry for exact dims)."""
from repro.configs.registry import get_config

CONFIG = get_config("mistral-nemo-12b")
SMOKE = get_config("mistral-nemo-12b-smoke")
