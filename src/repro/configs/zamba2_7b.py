"""Arch config: zamba2-7b (see repro.configs.registry for exact dims)."""
from repro.configs.registry import get_config

CONFIG = get_config("zamba2-7b")
SMOKE = get_config("zamba2-7b-smoke")
