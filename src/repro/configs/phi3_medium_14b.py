"""Arch config: phi3-medium-14b (see repro.configs.registry for exact dims)."""
from repro.configs.registry import get_config

CONFIG = get_config("phi3-medium-14b")
SMOKE = get_config("phi3-medium-14b-smoke")
