"""Arch config: llama4-maverick-400b-a17b (see repro.configs.registry for exact dims)."""
from repro.configs.registry import get_config

CONFIG = get_config("llama4-maverick-400b-a17b")
SMOKE = get_config("llama4-maverick-400b-a17b-smoke")
