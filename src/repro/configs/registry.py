"""The 10 assigned architectures (exact dims from the assignment) + shape
grid + reduced smoke variants.

Sources are tagged in each config docstring; vocabs are padded minimally when
needed for clean sharding over the 16-way ``model`` axis (noted inline).
"""
from __future__ import annotations

import dataclasses
from dataclasses import replace
from typing import Dict, Tuple

import jax.numpy as jnp

from repro.models.common import ArchConfig

# ---------------------------------------------------------------------------
# Shapes (assignment): name -> (seq_len, global_batch, kind)
#   kind: train | prefill | decode | long_decode
# ---------------------------------------------------------------------------
SHAPES: Dict[str, Tuple[int, int, str]] = {
    "train_4k": (4096, 256, "train"),
    "prefill_32k": (32768, 32, "prefill"),
    "decode_32k": (32768, 128, "decode"),
    "long_500k": (524288, 1, "long_decode"),
}

# ---------------------------------------------------------------------------
# Architectures.
# ---------------------------------------------------------------------------
ARCHS: Dict[str, ArchConfig] = {}


def _reg(cfg: ArchConfig) -> ArchConfig:
    ARCHS[cfg.name] = cfg
    return cfg


# [ssm] Finch — data-dependent decay [arXiv:2404.05892; hf]
RWKV6_7B = _reg(ArchConfig(
    name="rwkv6-7b", family="ssm", n_layers=32, d_model=4096,
    n_heads=64, n_kv_heads=64, head_dim=64, d_ff=14336, vocab=65536,
))

# [dense] 128k ctx [hf:mistralai/Mistral-Nemo-Base-2407]
MISTRAL_NEMO_12B = _reg(ArchConfig(
    name="mistral-nemo-12b", family="dense", n_layers=40, d_model=5120,
    n_heads=32, n_kv_heads=8, head_dim=128, d_ff=14336, vocab=131072,
    rope_theta=1e6, skip_shapes=("long_500k",),
))

# [dense] RoPE SwiGLU GQA [arXiv:2404.14219]
PHI3_MEDIUM_14B = _reg(ArchConfig(
    name="phi3-medium-14b", family="dense", n_layers=40, d_model=5120,
    n_heads=40, n_kv_heads=10, head_dim=128, d_ff=17920, vocab=100352,
    rope_theta=1e4, skip_shapes=("long_500k",),
    pad_heads_to=48,  # 40 heads don't divide the 16-way TP axis (§Perf)
))

# [dense] llama-arch GQA [arXiv:2403.04652]
YI_9B = _reg(ArchConfig(
    name="yi-9b", family="dense", n_layers=48, d_model=4096,
    n_heads=32, n_kv_heads=4, head_dim=128, d_ff=11008, vocab=64000,
    rope_theta=5e6, skip_shapes=("long_500k",),
))

# [dense] GQA, QKV bias [hf:Qwen/Qwen2.5]
QWEN25_3B = _reg(ArchConfig(
    name="qwen2.5-3b", family="dense", n_layers=36, d_model=2048,
    n_heads=16, n_kv_heads=2, head_dim=128, d_ff=11008, vocab=151936,
    rope_theta=1e6, qkv_bias=True, skip_shapes=("long_500k",),
))

# [moe] 8 experts top-2, SWA [arXiv:2401.04088] — SWA(4096) makes long-context
# decode sub-quadratic, so long_500k RUNS for mixtral.
MIXTRAL_8X7B = _reg(ArchConfig(
    name="mixtral-8x7b", family="moe", n_layers=32, d_model=4096,
    n_heads=32, n_kv_heads=8, head_dim=128, d_ff=14336, vocab=32000,
    rope_theta=1e6, sliding_window=4096, n_experts=8, top_k=2,
    moe_every=1, moe_group=512,
))

# [moe] MoE 128e top-1, interleaved dense/MoE, early fusion
# [hf:meta-llama/Llama-4]; bf16 params + bf16 moments to fit 256 chips.
LLAMA4_MAVERICK = _reg(ArchConfig(
    name="llama4-maverick-400b-a17b", family="moe", n_layers=48,
    d_model=5120, n_heads=40, n_kv_heads=8, head_dim=128, d_ff=8192,
    vocab=202048, rope_theta=5e5, n_experts=128, top_k=1, moe_every=2,
    moe_group=1024, param_dtype=jnp.bfloat16, moment_dtype=jnp.bfloat16,
    pad_heads_to=48,  # 40 heads -> 48 for 16-way TP (§Perf)
    skip_shapes=("long_500k",),
))

# [audio] enc-dec, multimodal [arXiv:2308.11596] — 24 enc + 24 dec layers,
# vocab padded 256206 -> 256256 for 16-way sharding.
SEAMLESS_M4T_V2 = _reg(ArchConfig(
    name="seamless-m4t-large-v2", family="encdec", n_layers=24,
    n_enc_layers=24, d_model=1024, n_heads=16, n_kv_heads=16, head_dim=64,
    d_ff=8192, vocab=256256, rope_theta=1e4, frontend="audio",
    frontend_tokens=1024, skip_shapes=("long_500k",),
))

# [vlm] InternViT + InternLM2/Qwen2-ish backbone [arXiv:2404.16821] —
# vocab padded 151655 -> 151680.
INTERNVL2_1B = _reg(ArchConfig(
    name="internvl2-1b", family="vlm", n_layers=24, d_model=896,
    n_heads=14, n_kv_heads=2, head_dim=64, d_ff=4864, vocab=151680,
    rope_theta=1e6, qkv_bias=True, frontend="vit", frontend_tokens=256,
    pad_heads_to=16,  # 14 heads -> 16 for 16-way TP (§Perf)
    skip_shapes=("long_500k",),
))

# [hybrid] Mamba2 + shared attn blocks [arXiv:2411.15242]
ZAMBA2_7B = _reg(ArchConfig(
    name="zamba2-7b", family="hybrid", n_layers=81, d_model=3584,
    n_heads=32, n_kv_heads=32, head_dim=112, d_ff=14336, vocab=32000,
    rope_theta=1e4, ssm_state=64, shared_attn_every=6,
))


# ---------------------------------------------------------------------------
# Reduced (smoke) variants: same family/topology, tiny dims.
# ---------------------------------------------------------------------------
def reduced(cfg: ArchConfig) -> ArchConfig:
    n_layers = {"zamba2-7b": 7}.get(cfg.name, 2 * max(cfg.moe_every, 1))
    kw = dict(
        name=cfg.name + "-smoke",
        n_layers=n_layers,
        d_model=128,
        n_heads=4, n_kv_heads=min(cfg.n_kv_heads, 2), head_dim=32,
        d_ff=256, vocab=512,
        dtype=jnp.float32, param_dtype=jnp.float32,
        remat="none",
        frontend_tokens=8 if cfg.frontend != "none" else cfg.frontend_tokens,
        moe_group=64,
        pad_heads_to=0,
    )
    if cfg.family == "ssm":
        kw.update(n_heads=2, n_kv_heads=2, head_dim=64)   # rwkv hd=64
    if cfg.family == "hybrid":
        kw.update(ssm_state=16, shared_attn_every=3, n_heads=4,
                  n_kv_heads=4, head_dim=32)
    if cfg.n_experts:
        kw.update(n_experts=4, top_k=min(cfg.top_k, 2))
    if cfg.family == "encdec":
        kw.update(n_enc_layers=2)
    if cfg.n_kv_heads == cfg.n_heads:  # MHA archs stay MHA
        kw.update(n_kv_heads=kw["n_heads"])
    return replace(cfg, **kw)


def get_config(name: str) -> ArchConfig:
    if name.endswith("-smoke"):
        return reduced(ARCHS[name[:-len("-smoke")]])
    return ARCHS[name]


def arch_names() -> list[str]:
    return list(ARCHS.keys())


def cells() -> list[tuple[str, str]]:
    """All (arch, shape) cells incl. skips (caller filters on skip_shapes)."""
    return [(a, s) for a in ARCHS for s in SHAPES]
