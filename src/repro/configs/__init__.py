from repro.configs.registry import (  # noqa: F401
    ARCHS, SHAPES, arch_names, cells, get_config, reduced)
