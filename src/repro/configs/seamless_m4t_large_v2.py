"""Arch config: seamless-m4t-large-v2 (see repro.configs.registry for exact dims)."""
from repro.configs.registry import get_config

CONFIG = get_config("seamless-m4t-large-v2")
SMOKE = get_config("seamless-m4t-large-v2-smoke")
