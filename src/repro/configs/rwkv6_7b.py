"""Arch config: rwkv6-7b (see repro.configs.registry for exact dims)."""
from repro.configs.registry import get_config

CONFIG = get_config("rwkv6-7b")
SMOKE = get_config("rwkv6-7b-smoke")
