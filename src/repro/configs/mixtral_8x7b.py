"""Arch config: mixtral-8x7b (see repro.configs.registry for exact dims)."""
from repro.configs.registry import get_config

CONFIG = get_config("mixtral-8x7b")
SMOKE = get_config("mixtral-8x7b-smoke")
