"""Arch config: yi-9b (see repro.configs.registry for exact dims)."""
from repro.configs.registry import get_config

CONFIG = get_config("yi-9b")
SMOKE = get_config("yi-9b-smoke")
