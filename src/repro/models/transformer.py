"""Decoder-only transformer LM (dense GQA and MoE variants) with
scan-over-layers, remat, and a KV-cache serving path.

Layers are stacked into *blocks* so heterogeneous stacks still scan:
  - dense archs: block = 1 dense layer
  - mixtral: block = 1 MoE layer
  - llama4 (interleaved): block = ``moe_every`` layers, the last one MoE.
"""
from __future__ import annotations

from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models import common as C
from repro.models import mlp
from repro.models.common import ArchConfig, param
from repro.parallel.sharding import hint_batch


# ---------------------------------------------------------------------------
# Block = smallest repeating unit.
# ---------------------------------------------------------------------------
def _block_layout(cfg: ArchConfig) -> list[str]:
    """Kinds of the layers inside one block: 'dense' | 'moe'."""
    if cfg.n_experts == 0:
        return ["dense"]
    if cfg.moe_every == 1:
        return ["moe"]
    return ["dense"] * (cfg.moe_every - 1) + ["moe"]


def n_blocks(cfg: ArchConfig) -> int:
    per = len(_block_layout(cfg))
    assert cfg.n_layers % per == 0, (cfg.n_layers, per)
    return cfg.n_layers // per


def init_block(key, cfg: ArchConfig):
    layers = []
    for kind in _block_layout(cfg):
        key, k1, k2, k3 = jax.random.split(key, 4)
        layer = {
            "ln1": param(k3, (cfg.d_model,), ("embed",), cfg.param_dtype,
                         init="zeros"),
            "ln2": param(k3, (cfg.d_model,), ("embed",), cfg.param_dtype,
                         init="zeros"),
            "attn": attn.init(k1, cfg),
            "mlp": mlp.init_moe(k2, cfg) if kind == "moe"
                   else mlp.init_dense(k2, cfg),
        }
        layers.append(layer)
    return {"layers": layers}


def init(key, cfg: ArchConfig):
    kb, ke = jax.random.split(key)
    keys = jax.random.split(kb, n_blocks(cfg))
    blocks = jax.vmap(lambda k: init_block(k, cfg))(keys)
    return {"blocks": blocks, "embed": C.embed_init(ke, cfg)}


# ---------------------------------------------------------------------------
# Forward (training).
# ---------------------------------------------------------------------------
def _block_train(bp, x, cfg: ArchConfig):
    x = hint_batch(x)
    for kind, lp in zip(_block_layout(cfg), bp["layers"]):
        h = C.rmsnorm(x, lp["ln1"])
        x = x + attn.forward_train(lp["attn"], h, cfg)
        h = C.rmsnorm(x, lp["ln2"])
        if kind == "moe":
            x = x + mlp.forward_moe(lp["mlp"], h, cfg)
        else:
            x = x + mlp.forward_dense(lp["mlp"], h, cfg)
    return x


def forward(params, tokens, cfg: ArchConfig,
            inputs_embeds: jnp.ndarray | None = None) -> jnp.ndarray:
    """tokens: i32[B, S] -> logits f32[B, S, V]."""
    x = C.embed_tokens(params["embed"], tokens, cfg)
    if inputs_embeds is not None:   # vlm: prepend precomputed patch embeds
        x = jnp.concatenate([inputs_embeds.astype(cfg.dtype), x], axis=1)

    body = C.make_remat(lambda xx, bp: _block_train(bp, xx, cfg), cfg.remat)

    def scan_fn(xx, bp):
        return body(xx, bp), None

    x, _ = jax.lax.scan(scan_fn, x, params["blocks"],
                        unroll=cfg.scan_unroll)
    if inputs_embeds is not None:
        x = x[:, inputs_embeds.shape[1]:]
    return C.lm_head(params["embed"], x, cfg)


# ---------------------------------------------------------------------------
# Serving.
# ---------------------------------------------------------------------------
class DecodeState(NamedTuple):
    caches: Any          # stacked KVCache pytree [n_blocks, n_layers_per, ...]
    pos: jnp.ndarray     # [] int32 next position


def init_cache(cfg: ArchConfig, batch: int, max_len: int) -> Any:
    per = len(_block_layout(cfg))
    nb = n_blocks(cfg)

    def one(_):
        return [attn.init_cache(cfg, batch, max_len) for _ in range(per)]
    # stacked along block axis
    caches = jax.tree_util.tree_map(
        lambda x: jnp.broadcast_to(x, (nb,) + x.shape),
        one(None))
    return caches


def _block_prefill(bp, x, cfg: ArchConfig, max_len: int):
    new_caches = []
    for kind, lp in zip(_block_layout(cfg), bp["layers"]):
        h = C.rmsnorm(x, lp["ln1"])
        a, cache = attn.forward_prefill(lp["attn"], h, cfg, max_len)
        x = x + a
        h = C.rmsnorm(x, lp["ln2"])
        if kind == "moe":
            x = x + mlp.forward_moe(lp["mlp"], h, cfg)
        else:
            x = x + mlp.forward_dense(lp["mlp"], h, cfg)
        new_caches.append(cache)
    return x, new_caches


def prefill(params, tokens, cfg: ArchConfig, max_len: int):
    """Returns (last-position logits f32[B, V], DecodeState)."""
    x = C.embed_tokens(params["embed"], tokens, cfg)

    def scan_fn(xx, bp):
        xx, caches = _block_prefill(bp, xx, cfg, max_len)
        return xx, caches

    x, caches = jax.lax.scan(scan_fn, x, params["blocks"],
                             unroll=cfg.scan_unroll)
    logits = C.lm_head(params["embed"], x[:, -1:], cfg)[:, 0]
    return logits, DecodeState(caches, jnp.int32(tokens.shape[1]))


def _block_decode(bp, x, caches, pos, cfg: ArchConfig):
    new_caches = []
    for i, (kind, lp) in enumerate(zip(_block_layout(cfg), bp["layers"])):
        h = C.rmsnorm(x, lp["ln1"])
        a, cache = attn.forward_decode(lp["attn"], h, caches[i], pos, cfg)
        x = x + a
        h = C.rmsnorm(x, lp["ln2"])
        if kind == "moe":
            x = x + mlp.forward_moe(lp["mlp"], h, cfg)
        else:
            x = x + mlp.forward_dense(lp["mlp"], h, cfg)
        new_caches.append(cache)
    return x, new_caches


def decode_step(params, token, state: DecodeState, cfg: ArchConfig):
    """token: i32[B] -> (logits f32[B, V], new DecodeState)."""
    x = C.embed_tokens(params["embed"], token[:, None], cfg)

    def scan_fn(xx, block):
        bp, caches = block
        xx, new_caches = _block_decode(bp, xx, caches, state.pos, cfg)
        return xx, new_caches

    x, caches = jax.lax.scan(scan_fn, x, (params["blocks"], state.caches),
                             unroll=cfg.scan_unroll)
    logits = C.lm_head(params["embed"], x, cfg)[:, 0]
    return logits, DecodeState(caches, state.pos + 1)
