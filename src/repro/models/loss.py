"""LM losses: next-token cross entropy (f32 logits) + z-loss + MoE aux."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def next_token_loss(logits: jnp.ndarray, tokens: jnp.ndarray,
                    z_loss: float = 1e-4) -> jnp.ndarray:
    """logits: f32[B, S, V]; tokens: i32[B, S]. Shifted CE, mean over tokens."""
    lg = logits[:, :-1].astype(jnp.float32)
    tg = tokens[:, 1:]
    lse = jax.scipy.special.logsumexp(lg, axis=-1)
    true = jnp.take_along_axis(lg, tg[..., None], axis=-1)[..., 0]
    ce = lse - true
    zl = z_loss * jnp.square(lse)
    return jnp.mean(ce + zl)
