"""Encoder-decoder transformer (SeamlessM4T-large-v2 backbone).

The audio frontend (w2v-BERT conformer feature extractor) is a STUB per the
assignment: ``input_specs`` provides precomputed frame embeddings
[B, T_frames, d_model]. We model the text decoder faithfully: self-attention
(causal, KV-cached) + cross-attention to the encoder output + SwiGLU MLP.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models import common as C
from repro.models import mlp
from repro.models.common import ArchConfig, param
from repro.parallel.sharding import hint_batch


def init_enc_layer(key, cfg: ArchConfig):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "ln1": param(k3, (cfg.d_model,), ("embed",), cfg.param_dtype,
                     init="zeros"),
        "ln2": param(k3, (cfg.d_model,), ("embed",), cfg.param_dtype,
                     init="zeros"),
        "attn": attn.init(k1, cfg),
        "mlp": mlp.init_dense(k2, cfg),
    }


def init_dec_layer(key, cfg: ArchConfig):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "ln1": param(k4, (cfg.d_model,), ("embed",), cfg.param_dtype,
                     init="zeros"),
        "ln2": param(k4, (cfg.d_model,), ("embed",), cfg.param_dtype,
                     init="zeros"),
        "ln3": param(k4, (cfg.d_model,), ("embed",), cfg.param_dtype,
                     init="zeros"),
        "self_attn": attn.init(k1, cfg),
        "cross_attn": attn.init(k2, cfg),
        "mlp": mlp.init_dense(k3, cfg),
    }


def init(key, cfg: ArchConfig):
    ke, kd, kem = jax.random.split(key, 3)
    enc = jax.vmap(lambda k: init_enc_layer(k, cfg))(
        jax.random.split(ke, cfg.n_enc_layers))
    dec = jax.vmap(lambda k: init_dec_layer(k, cfg))(
        jax.random.split(kd, cfg.n_layers))
    return {"enc": enc, "dec": dec, "embed": C.embed_init(kem, cfg)}


def encode(params, frames, cfg: ArchConfig):
    """frames: f32[B, T, D] precomputed frontend embeddings."""
    x = frames.astype(cfg.dtype)

    def body(xx, lp):
        xx = hint_batch(xx)
        h = C.rmsnorm(xx, lp["ln1"])
        xx = xx + attn.forward_train(lp["attn"], h, cfg, bidirectional=True)
        h = C.rmsnorm(xx, lp["ln2"])
        xx = xx + mlp.forward_dense(lp["mlp"], h, cfg)
        return xx

    fn = C.make_remat(body, cfg.remat)
    x, _ = jax.lax.scan(lambda xx, lp: (fn(xx, lp), None), x, params["enc"],
                        unroll=cfg.scan_unroll)
    return x


def _dec_block(lp, x, enc_out, cfg: ArchConfig):
    x = hint_batch(x)
    h = C.rmsnorm(x, lp["ln1"])
    x = x + attn.forward_train(lp["self_attn"], h, cfg)
    h = C.rmsnorm(x, lp["ln2"])
    x = x + attn.forward_cross(lp["cross_attn"], h, enc_out, cfg)
    h = C.rmsnorm(x, lp["ln3"])
    return x + mlp.forward_dense(lp["mlp"], h, cfg)


def forward(params, tokens, cfg: ArchConfig, frames=None, **_):
    """Training: teacher-forced decode over target tokens."""
    enc_out = encode(params, frames, cfg)
    x = C.embed_tokens(params["embed"], tokens, cfg)
    body = C.make_remat(
        lambda xx, lp: _dec_block(lp, xx, enc_out, cfg), cfg.remat)
    x, _ = jax.lax.scan(lambda xx, lp: (body(xx, lp), None), x,
                        params["dec"], unroll=cfg.scan_unroll)
    return C.lm_head(params["embed"], x, cfg)


# ---------------------------------------------------------------------------
# Serving.
# ---------------------------------------------------------------------------
class EncDecState(NamedTuple):
    self_caches: Any       # stacked KVCache [L, ...]
    enc_out: jnp.ndarray   # [B, T, D]
    pos: jnp.ndarray


def make_decode_state(cfg: ArchConfig, batch: int, max_len: int,
                      pos: int) -> EncDecState:
    """Decode state from scratch: empty self-attn caches + a stand-in
    encoder output (T_src = max_len // 4, the frontend-stub stride)."""
    kv = attn.init_cache(cfg, batch, max_len)
    caches = jax.tree_util.tree_map(
        lambda z: jnp.broadcast_to(z, (cfg.n_layers,) + z.shape), kv)
    t_src = max(max_len // 4, 8)
    enc_out = jnp.zeros((batch, t_src, cfg.d_model), cfg.dtype)
    return EncDecState(caches, enc_out, jnp.int32(pos))


def prefill(params, tokens, cfg: ArchConfig, max_len: int, frames=None):
    enc_out = encode(params, frames, cfg)
    x = C.embed_tokens(params["embed"], tokens, cfg)

    def scan_fn(xx, lp):
        h = C.rmsnorm(xx, lp["ln1"])
        a, cache = attn.forward_prefill(lp["self_attn"], h, cfg, max_len)
        xx = xx + a
        h = C.rmsnorm(xx, lp["ln2"])
        xx = xx + attn.forward_cross(lp["cross_attn"], h, enc_out, cfg)
        h = C.rmsnorm(xx, lp["ln3"])
        xx = xx + mlp.forward_dense(lp["mlp"], h, cfg)
        return xx, cache

    x, caches = jax.lax.scan(scan_fn, x, params["dec"],
                             unroll=cfg.scan_unroll)
    logits = C.lm_head(params["embed"], x[:, -1:], cfg)[:, 0]
    return logits, EncDecState(caches, enc_out, jnp.int32(tokens.shape[1]))


def decode_step(params, token, state: EncDecState, cfg: ArchConfig):
    x = C.embed_tokens(params["embed"], token[:, None], cfg)

    def scan_fn(xx, inp):
        lp, cache = inp
        h = C.rmsnorm(xx, lp["ln1"])
        a, new_cache = attn.forward_decode(lp["self_attn"], h, cache,
                                           state.pos, cfg)
        xx = xx + a
        h = C.rmsnorm(xx, lp["ln2"])
        xx = xx + attn.forward_cross(lp["cross_attn"], h, state.enc_out, cfg)
        h = C.rmsnorm(xx, lp["ln3"])
        xx = xx + mlp.forward_dense(lp["mlp"], h, cfg)
        return xx, new_cache

    x, caches = jax.lax.scan(scan_fn, x, (params["dec"], state.self_caches),
                             unroll=cfg.scan_unroll)
    logits = C.lm_head(params["embed"], x, cfg)[:, 0]
    return logits, EncDecState(caches, state.enc_out, state.pos + 1)
