"""Mamba2 (SSD) blocks and the Zamba2 hybrid (arXiv:2411.15242):
Mamba2 backbone with a *shared* transformer block invoked every
``shared_attn_every`` SSM layers (weights shared across invocations; the
per-invocation LoRA adapters of the real model are omitted).

SSD recurrence per head (state S in R^{P x N}, scalar decay a_t per head):
    S_t = a_t S_{t-1} + (dt_t x_t) (x) B_t
    y_t = S_t C_t + D x_t
Chunked training form mirrors repro.kernels.mamba2_ssd.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.kernels.mamba2_ssd import ops as ssd_ops
from repro.models import attention as attn
from repro.models import common as C
from repro.models import mlp
from repro.models.common import ArchConfig, param
from repro.parallel.sharding import hint_axes, hint_batch

P_HEAD = 64  # mamba2 head dim


def _dims(cfg: ArchConfig):
    d_inner = 2 * cfg.d_model
    n_heads = d_inner // P_HEAD
    return d_inner, n_heads, cfg.ssm_state


def init_ssm_layer(key, cfg: ArchConfig):
    D = cfg.d_model
    d_inner, H, N = _dims(cfg)
    ks = jax.random.split(key, 6)
    pd = cfg.param_dtype
    conv_ch = d_inner + 2 * N
    return {
        "ln": param(ks[0], (D,), ("embed",), pd, init="zeros"),
        # fused input projection: [z, x, B, C, dt]
        "in_proj": param(ks[1], (D, 2 * d_inner + 2 * N + H),
                         ("embed", "mlp"), pd),
        "conv_w": param(ks[2], (cfg.conv_kernel, conv_ch),
                        ("unsharded", "mlp"), pd, scale=0.5),
        "conv_b": param(ks[2], (conv_ch,), ("mlp",), pd, init="zeros"),
        "A_log": param(ks[3], (H,), ("unsharded",), pd, init="zeros"),
        "dt_bias": param(ks[4], (H,), ("unsharded",), pd, init="zeros"),
        "D": param(ks[3], (H,), ("unsharded",), pd, init="ones"),
        "out_proj": param(ks[5], (d_inner, D), ("mlp", "embed"), pd),
    }


def init_shared_block(key, cfg: ArchConfig):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "ln1": param(k3, (cfg.d_model,), ("embed",), cfg.param_dtype,
                     init="zeros"),
        "ln2": param(k3, (cfg.d_model,), ("embed",), cfg.param_dtype,
                     init="zeros"),
        "attn": attn.init(k1, cfg),
        "mlp": mlp.init_dense(k2, cfg),
    }


def init(key, cfg: ArchConfig):
    kb, ks, ke = jax.random.split(key, 3)
    n_groups, tail = divmod(cfg.n_layers, max(cfg.shared_attn_every, 1))
    keys = jax.random.split(kb, cfg.n_layers)
    layers = jax.vmap(lambda k: init_ssm_layer(k, cfg))(keys)
    return {"blocks": layers,
            "shared": init_shared_block(ks, cfg),
            "embed": C.embed_init(ke, cfg)}


# ---------------------------------------------------------------------------
# Mamba2 block forward (training).
# ---------------------------------------------------------------------------
def _split_proj(zxbcdt, cfg: ArchConfig):
    d_inner, H, N = _dims(cfg)
    z = zxbcdt[..., :d_inner]
    x = zxbcdt[..., d_inner:2 * d_inner]
    B = zxbcdt[..., 2 * d_inner:2 * d_inner + N]
    Cc = zxbcdt[..., 2 * d_inner + N:2 * d_inner + 2 * N]
    dt = zxbcdt[..., 2 * d_inner + 2 * N:]
    return z, x, B, Cc, dt


def _causal_conv(x, w, b, cfg: ArchConfig):
    """Depthwise causal conv over time. x: [B,S,C]; w: [K,C]."""
    K = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    out = sum(xp[:, i:i + x.shape[1], :] * w[i][None, None, :]
              for i in range(K))
    return jax.nn.silu(out + b[None, None, :])


def _ssm_layer(lp, xres, cfg: ArchConfig):
    xres = hint_batch(xres)
    Bsz, S, D = xres.shape
    d_inner, H, N = _dims(cfg)
    h = C.rmsnorm(xres, lp["ln"])
    zxbcdt = jnp.einsum("bsd,de->bse", h, lp["in_proj"].astype(cfg.dtype))
    z, x, Bc, Cc, dt = _split_proj(zxbcdt, cfg)
    xbc = jnp.concatenate([x, Bc, Cc], axis=-1)
    xbc = _causal_conv(xbc, lp["conv_w"].astype(cfg.dtype),
                       lp["conv_b"].astype(cfg.dtype), cfg)
    x = xbc[..., :d_inner]
    Bc = xbc[..., d_inner:d_inner + N]
    Cc = xbc[..., d_inner + N:]
    dt = jax.nn.softplus(dt.astype(jnp.float32) +
                         lp["dt_bias"].astype(jnp.float32))  # [B,S,H]
    a = jnp.exp(-jnp.exp(lp["A_log"].astype(jnp.float32)) * dt)  # decay/head

    # pin SSD-scan layouts: heads stay TP-sharded, B/C explicitly
    # replicated — otherwise the partitioner resharding per chunk shows up
    # as ~1 TB of collective-permutes (§Perf iter 5)
    xh = hint_axes(x.reshape(Bsz, S, H, P_HEAD),
                   ("batch", None, "model", None))
    dt = hint_axes(dt, ("batch", None, "model"))
    a = hint_axes(a, ("batch", None, "model"))
    Bc = hint_axes(Bc, ("batch", None, None))
    Cc = hint_axes(Cc, ("batch", None, None))
    y = ssd_ops.ssd(xh, dt, a, Bc, Cc)                        # [B,S,H,P]
    y = y + lp["D"].astype(jnp.float32)[None, None, :, None] * \
        xh.astype(jnp.float32)
    y = y.reshape(Bsz, S, d_inner).astype(cfg.dtype) * jax.nn.silu(z)
    return xres + jnp.einsum("bse,ed->bsd", y,
                             lp["out_proj"].astype(cfg.dtype))


def _shared_block(sp, x, cfg: ArchConfig):
    h = C.rmsnorm(x, sp["ln1"])
    x = x + attn.forward_train(sp["attn"], h, cfg)
    h = C.rmsnorm(x, sp["ln2"])
    return x + mlp.forward_dense(sp["mlp"], h, cfg)


def forward(params, tokens, cfg: ArchConfig, **_) -> jnp.ndarray:
    x = C.embed_tokens(params["embed"], tokens, cfg)
    every = max(cfg.shared_attn_every, 1)
    n_groups, tail = divmod(cfg.n_layers, every)
    blocks = params["blocks"]
    grouped = jax.tree_util.tree_map(
        lambda p: p[:n_groups * every].reshape((n_groups, every) + p.shape[1:]),
        blocks)
    tail_p = jax.tree_util.tree_map(lambda p: p[n_groups * every:], blocks)

    ssm_body = C.make_remat(lambda xx, lp: _ssm_layer(lp, xx, cfg), cfg.remat)

    def group_fn(xx, gp):
        def inner(xx2, lp):
            return ssm_body(xx2, lp), None
        xx, _ = jax.lax.scan(inner, xx, gp, unroll=cfg.scan_unroll)
        xx = _shared_block(params["shared"], xx, cfg)
        return xx, None

    x, _ = jax.lax.scan(group_fn, x, grouped, unroll=cfg.scan_unroll)
    if tail:
        def inner(xx2, lp):
            return ssm_body(xx2, lp), None
        x, _ = jax.lax.scan(inner, x, tail_p, unroll=cfg.scan_unroll)
    return C.lm_head(params["embed"], x, cfg)


# ---------------------------------------------------------------------------
# Serving.
# ---------------------------------------------------------------------------
class MambaState(NamedTuple):
    ssd: jnp.ndarray        # [L, B, H, P, N]
    conv: jnp.ndarray       # [L, B, K-1, conv_ch]
    shared_caches: Any      # list-stacked KVCache [n_shared, ...]
    pos: jnp.ndarray


def init_cache(cfg: ArchConfig, batch: int, max_len: int) -> MambaState:
    d_inner, H, N = _dims(cfg)
    L = cfg.n_layers
    conv_ch = d_inner + 2 * N
    every = max(cfg.shared_attn_every, 1)
    n_shared = cfg.n_layers // every
    kv = attn.init_cache(cfg, batch, max_len)
    shared = jax.tree_util.tree_map(
        lambda z: jnp.broadcast_to(z, (n_shared,) + z.shape), kv)
    return MambaState(
        jnp.zeros((L, batch, H, P_HEAD, N), jnp.float32),
        jnp.zeros((L, batch, cfg.conv_kernel - 1, conv_ch), cfg.dtype),
        shared, jnp.int32(0))


def _ssm_step(lp, x1, ssd_s, conv_s, cfg: ArchConfig):
    """Single-token step. x1: [B, D]."""
    Bsz, D = x1.shape
    d_inner, H, N = _dims(cfg)
    h = C.rmsnorm(x1, lp["ln"])
    zxbcdt = h @ lp["in_proj"].astype(cfg.dtype)
    z, x, Bc, Cc, dt = _split_proj(zxbcdt, cfg)
    xbc = jnp.concatenate([x, Bc, Cc], axis=-1)          # [B, conv_ch]
    hist = jnp.concatenate([conv_s, xbc[:, None, :]], axis=1)  # [B,K,ch]
    w = lp["conv_w"].astype(cfg.dtype)
    out = jnp.einsum("bkc,kc->bc", hist, w) + lp["conv_b"].astype(cfg.dtype)
    xbc = jax.nn.silu(out)
    x = xbc[..., :d_inner]
    Bc = xbc[..., d_inner:d_inner + N]
    Cc = xbc[..., d_inner + N:]
    dt = jax.nn.softplus(dt.astype(jnp.float32) +
                         lp["dt_bias"].astype(jnp.float32))       # [B,H]
    a = jnp.exp(-jnp.exp(lp["A_log"].astype(jnp.float32)) * dt)
    xh = x.reshape(Bsz, H, P_HEAD).astype(jnp.float32)
    dbx = (dt[..., None] * xh)                                   # [B,H,P]
    ssd_new = a[..., None, None] * ssd_s + \
        dbx[..., :, None] * Bc.astype(jnp.float32)[:, None, None, :]
    y = jnp.einsum("bhpn,bn->bhp", ssd_new, Cc.astype(jnp.float32))
    y = y + lp["D"].astype(jnp.float32)[None, :, None] * xh
    y = y.reshape(Bsz, d_inner).astype(cfg.dtype) * jax.nn.silu(z)
    x1 = x1 + y @ lp["out_proj"].astype(cfg.dtype)
    return x1, ssd_new, hist[:, 1:, :]


def _ssm_layer_with_state(lp, xres, cfg: ArchConfig):
    """Like _ssm_layer but also returns (ssd_state, conv_state)."""
    Bsz, S, D = xres.shape
    d_inner, H, N = _dims(cfg)
    h = C.rmsnorm(xres, lp["ln"])
    zxbcdt = jnp.einsum("bsd,de->bse", h, lp["in_proj"].astype(cfg.dtype))
    z, x, Bc, Cc, dt = _split_proj(zxbcdt, cfg)
    xbc_raw = jnp.concatenate([x, Bc, Cc], axis=-1)
    conv_state = xbc_raw[:, -(cfg.conv_kernel - 1):, :]
    xbc = _causal_conv(xbc_raw, lp["conv_w"].astype(cfg.dtype),
                       lp["conv_b"].astype(cfg.dtype), cfg)
    x = xbc[..., :d_inner]
    Bc = xbc[..., d_inner:d_inner + N]
    Cc = xbc[..., d_inner + N:]
    dt = jax.nn.softplus(dt.astype(jnp.float32) +
                         lp["dt_bias"].astype(jnp.float32))
    a = jnp.exp(-jnp.exp(lp["A_log"].astype(jnp.float32)) * dt)
    xh = x.reshape(Bsz, S, H, P_HEAD)
    y, ssd_state = ssd_ops.ssd_chunked(xh, dt, a, Bc, Cc)
    y = y + lp["D"].astype(jnp.float32)[None, None, :, None] * \
        xh.astype(jnp.float32)
    y = y.reshape(Bsz, S, d_inner).astype(cfg.dtype) * jax.nn.silu(z)
    out = xres + jnp.einsum("bse,ed->bsd", y,
                            lp["out_proj"].astype(cfg.dtype))
    return out, ssd_state, conv_state


def prefill(params, tokens, cfg: ArchConfig, max_len: int):
    """Prefill S tokens, returning (last logits, MambaState)."""
    B, S = tokens.shape
    x = C.embed_tokens(params["embed"], tokens, cfg)
    every = max(cfg.shared_attn_every, 1)
    n_groups, tail = divmod(cfg.n_layers, every)
    blocks = params["blocks"]
    grouped = jax.tree_util.tree_map(
        lambda p: p[:n_groups * every].reshape((n_groups, every) +
                                               p.shape[1:]), blocks)
    tail_p = jax.tree_util.tree_map(lambda p: p[n_groups * every:], blocks)

    def ssm_scan(xx, lp):
        xx, ssd_s, conv_s = _ssm_layer_with_state(lp, xx, cfg)
        return xx, (ssd_s, conv_s)

    def group_fn(xx, gp):
        xx, states = jax.lax.scan(ssm_scan, xx, gp,
                                  unroll=cfg.scan_unroll)
        h = C.rmsnorm(xx, params["shared"]["ln1"])
        a, cache = attn.forward_prefill(params["shared"]["attn"], h, cfg,
                                        max_len)
        xx = xx + a
        h = C.rmsnorm(xx, params["shared"]["ln2"])
        xx = xx + mlp.forward_dense(params["shared"]["mlp"], h, cfg)
        return xx, (states, cache)

    x, ((ssd_g, conv_g), caches) = jax.lax.scan(group_fn, x, grouped,
                                                unroll=cfg.scan_unroll)
    ssd_all = ssd_g.reshape((n_groups * every,) + ssd_g.shape[2:])
    conv_all = conv_g.reshape((n_groups * every,) + conv_g.shape[2:])
    if tail:
        x, (ssd_t, conv_t) = jax.lax.scan(ssm_scan, x, tail_p,
                                          unroll=cfg.scan_unroll)
        ssd_all = jnp.concatenate([ssd_all, ssd_t])
        conv_all = jnp.concatenate([conv_all, conv_t])
    logits = C.lm_head(params["embed"], x[:, -1:], cfg)[:, 0]
    return logits, MambaState(ssd_all, conv_all, caches, jnp.int32(S))


def decode_step(params, token, state: MambaState, cfg: ArchConfig):
    x = C.embed_tokens(params["embed"], token[:, None], cfg)[:, 0]
    every = max(cfg.shared_attn_every, 1)
    n_groups = cfg.n_layers // every
    tail = cfg.n_layers - n_groups * every
    blocks = params["blocks"]

    def regroup(p):
        return p[:n_groups * every].reshape((n_groups, every) + p.shape[1:])

    grouped = jax.tree_util.tree_map(regroup, blocks)
    g_ssd = regroup(state.ssd)
    g_conv = regroup(state.conv)
    tail_p = jax.tree_util.tree_map(lambda p: p[n_groups * every:], blocks)
    t_ssd = state.ssd[n_groups * every:]
    t_conv = state.conv[n_groups * every:]

    def ssm_scan(xx, inp):
        lp, ssd_s, conv_s = inp
        xx, ssd_new, conv_new = _ssm_step(lp, xx, ssd_s, conv_s, cfg)
        return xx, (ssd_new, conv_new)

    def group_fn(xx, inp):
        gp, ssd_g, conv_g, cache = inp
        xx, (ssd_new, conv_new) = jax.lax.scan(ssm_scan, xx,
                                               (gp, ssd_g, conv_g),
                                               unroll=cfg.scan_unroll)
        h = C.rmsnorm(xx, params["shared"]["ln1"])
        a, new_cache = attn.forward_decode(params["shared"]["attn"],
                                           h[:, None, :], cache, state.pos,
                                           cfg)
        xx = xx + a[:, 0]
        h = C.rmsnorm(xx, params["shared"]["ln2"])
        xx = xx + mlp.forward_dense(params["shared"]["mlp"], h[:, None, :],
                                    cfg)[:, 0]
        return xx, (ssd_new, conv_new, new_cache)

    x, (ssd_g, conv_g, caches) = jax.lax.scan(
        group_fn, x, (grouped, g_ssd, g_conv, state.shared_caches),
        unroll=cfg.scan_unroll)
    ssd_new = ssd_g.reshape((n_groups * every,) + ssd_g.shape[2:])
    conv_new = conv_g.reshape((n_groups * every,) + conv_g.shape[2:])
    if tail:
        x, (ssd_t, conv_t) = jax.lax.scan(ssm_scan, x,
                                          (tail_p, t_ssd, t_conv),
                                          unroll=cfg.scan_unroll)
        ssd_new = jnp.concatenate([ssd_new, ssd_t])
        conv_new = jnp.concatenate([conv_new, conv_t])

    logits = C.lm_head(params["embed"], x[:, None], cfg)[:, 0]
    return logits, MambaState(ssd_new, conv_new, caches, state.pos + 1)
