"""RWKV6 "Finch" (arXiv:2404.05892): attention-free LM with token-shift
time-mix, data-dependent decay (LoRA-produced per-channel w_t), WKV linear
recurrence, and squared-ReLU channel-mix.

Training uses the chunked WKV (repro.kernels.rwkv6_wkv); serving carries the
O(1) per-layer state (wkv state [H, hd, hd] + the two token-shift vectors) —
which is what makes ``long_500k`` decoding feasible for this family.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.kernels.rwkv6_wkv import ops as wkv_ops
from repro.models import common as C
from repro.models.common import ArchConfig, param
from repro.parallel.sharding import hint_batch

LORA_RANK = 64


def init_layer(key, cfg: ArchConfig):
    D, F = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 12)
    pd = cfg.param_dtype
    return {
        "ln1": param(ks[0], (D,), ("embed",), pd, init="zeros"),
        "ln2": param(ks[0], (D,), ("embed",), pd, init="zeros"),
        # time-mix lerp coefficients (token shift)
        "mu": param(ks[1], (5, D), ("unsharded", "embed"), pd, scale=0.5),
        "wr": param(ks[2], (D, D), ("embed", "heads_x_dim"), pd),
        "wk": param(ks[3], (D, D), ("embed", "heads_x_dim"), pd),
        "wv": param(ks[4], (D, D), ("embed", "heads_x_dim"), pd),
        "wg": param(ks[5], (D, D), ("embed", "heads_x_dim"), pd),
        "wo": param(ks[6], (D, D), ("heads_x_dim", "embed"), pd),
        # data-dependent decay: w_t = exp(-exp(w0 + tanh(x A) B))
        "w0": param(ks[7], (D,), ("embed",), pd, init="zeros"),
        "wA": param(ks[8], (D, LORA_RANK), ("embed", "unsharded"), pd),
        "wB": param(ks[9], (LORA_RANK, D), ("unsharded", "embed"), pd),
        "u": param(ks[10], (D,), ("embed",), pd, scale=0.3),
        "ln_x": param(ks[10], (D,), ("embed",), pd, init="zeros"),
        # channel mix
        "cm_mu": param(ks[1], (2, D), ("unsharded", "embed"), pd, scale=0.5),
        "cm_k": param(ks[11], (D, F), ("embed", "mlp"), pd),
        "cm_r": param(ks[11], (D, D), ("embed", "heads_x_dim"), pd),
        "cm_v": param(ks[11], (F, D), ("mlp", "embed"), pd),
    }


def init(key, cfg: ArchConfig):
    kb, ke = jax.random.split(key)
    keys = jax.random.split(kb, cfg.n_layers)
    layers = jax.vmap(lambda k: init_layer(k, cfg))(keys)
    return {"blocks": layers, "embed": C.embed_init(ke, cfg)}


def _shift(x, x_prev=None):
    """Token shift: previous token's features (zeros / carried for step 0)."""
    pad = jnp.zeros_like(x[:, :1]) if x_prev is None else x_prev[:, None]
    return jnp.concatenate([pad, x[:, :-1]], axis=1)


def _decay(lp, xw, cfg):
    lora = jnp.einsum("bsd,dr->bsr", jnp.tanh(
        jnp.einsum("bsd,dr->bsr", xw, lp["wA"].astype(cfg.dtype))),
        lp["wB"].astype(cfg.dtype).T.T)  # [B,S,D]
    w = jnp.exp(-jnp.exp(
        (lp["w0"].astype(jnp.float32) + lora.astype(jnp.float32))))
    return w  # in (0, 1)


def _time_mix(lp, x, cfg: ArchConfig, use_pallas: bool = False):
    B, S, D = x.shape
    H, hd = cfg.n_heads, cfg.hd
    sx = _shift(x)
    mu = lp["mu"].astype(cfg.dtype)
    xr, xk, xv, xw, xg = (x + mu[i] * (sx - x) for i in range(5))
    r = jnp.einsum("bsd,de->bse", xr, lp["wr"].astype(cfg.dtype))
    k = jnp.einsum("bsd,de->bse", xk, lp["wk"].astype(cfg.dtype))
    v = jnp.einsum("bsd,de->bse", xv, lp["wv"].astype(cfg.dtype))
    g = jax.nn.silu(jnp.einsum("bsd,de->bse", xg, lp["wg"].astype(cfg.dtype)))
    w = _decay(lp, xw, cfg)

    from repro.parallel.sharding import hint_axes

    def heads(z):
        # pin the WKV-scan input layout: heads TP-sharded (SPerf iter 5)
        return hint_axes(z.reshape(B, S, H, hd),
                         ("batch", None, "model", None))

    u = lp["u"].astype(jnp.float32).reshape(H, hd)
    y, _ = wkv_ops.wkv(heads(r), heads(k), heads(v), heads(w), u,
                       use_pallas=use_pallas)
    y = y.reshape(B, S, D)
    y = C.rmsnorm(y, lp["ln_x"])
    return jnp.einsum("bsd,de->bse", (y * g).astype(cfg.dtype),
                      lp["wo"].astype(cfg.dtype))


def _channel_mix(lp, x, cfg: ArchConfig):
    sx = _shift(x)
    mu = lp["cm_mu"].astype(cfg.dtype)
    xk = x + mu[0] * (sx - x)
    xr = x + mu[1] * (sx - x)
    k = jnp.einsum("bsd,df->bsf", xk, lp["cm_k"].astype(cfg.dtype))
    k = jnp.square(jax.nn.relu(k))
    r = jax.nn.sigmoid(jnp.einsum("bsd,de->bse", xr,
                                  lp["cm_r"].astype(cfg.dtype)))
    return r * jnp.einsum("bsf,fd->bsd", k, lp["cm_v"].astype(cfg.dtype))


def _block(lp, x, cfg: ArchConfig):
    x = hint_batch(x)
    x = x + _time_mix(lp, C.rmsnorm(x, lp["ln1"]), cfg)
    x = x + _channel_mix(lp, C.rmsnorm(x, lp["ln2"]), cfg)
    return x


def forward(params, tokens, cfg: ArchConfig, **_) -> jnp.ndarray:
    x = C.embed_tokens(params["embed"], tokens, cfg)
    body = C.make_remat(lambda xx, lp: _block(lp, xx, cfg), cfg.remat)
    x, _ = jax.lax.scan(lambda xx, lp: (body(xx, lp), None), x,
                        params["blocks"], unroll=cfg.scan_unroll)
    return C.lm_head(params["embed"], x, cfg)


# ---------------------------------------------------------------------------
# Serving: O(1) state per layer.
# ---------------------------------------------------------------------------
class RwkvState(NamedTuple):
    wkv: jnp.ndarray      # [L, B, H, hd, hd]
    tm_prev: jnp.ndarray  # [L, B, D] last token features (time mix)
    cm_prev: jnp.ndarray  # [L, B, D] last token features (channel mix)
    pos: jnp.ndarray


def init_cache(cfg: ArchConfig, batch: int, max_len: int = 0) -> RwkvState:
    L, B, D, H, hd = cfg.n_layers, batch, cfg.d_model, cfg.n_heads, cfg.hd
    return RwkvState(jnp.zeros((L, B, H, hd, hd), jnp.float32),
                     jnp.zeros((L, B, D), cfg.dtype),
                     jnp.zeros((L, B, D), cfg.dtype), jnp.int32(0))


def _layer_step(lp, x1, wkv_s, tm_prev, cm_prev, cfg: ArchConfig):
    """x1: [B, D] single token."""
    B, D = x1.shape
    H, hd = cfg.n_heads, cfg.hd
    h = C.rmsnorm(x1, lp["ln1"])
    mu = lp["mu"].astype(cfg.dtype)
    xr, xk, xv, xw, xg = (h + mu[i] * (tm_prev - h) for i in range(5))
    r = (xr @ lp["wr"].astype(cfg.dtype)).reshape(B, H, hd)
    k = (xk @ lp["wk"].astype(cfg.dtype)).reshape(B, H, hd)
    v = (xv @ lp["wv"].astype(cfg.dtype)).reshape(B, H, hd)
    g = jax.nn.silu(xg @ lp["wg"].astype(cfg.dtype))
    lora = jnp.tanh(xw @ lp["wA"].astype(cfg.dtype)) @ \
        lp["wB"].astype(cfg.dtype)
    w = jnp.exp(-jnp.exp(lp["w0"].astype(jnp.float32) +
                         lora.astype(jnp.float32))).reshape(B, H, hd)
    u = lp["u"].astype(jnp.float32).reshape(H, hd)
    y, wkv_new = wkv_ops.wkv_decode_step(r, k, v, w, u, wkv_s)
    y = C.rmsnorm(y.reshape(B, D), lp["ln_x"])
    x1 = x1 + ((y * g).astype(cfg.dtype) @ lp["wo"].astype(cfg.dtype))

    h2 = C.rmsnorm(x1, lp["ln2"])
    cmu = lp["cm_mu"].astype(cfg.dtype)
    xk2 = h2 + cmu[0] * (cm_prev - h2)
    xr2 = h2 + cmu[1] * (cm_prev - h2)
    kk = jnp.square(jax.nn.relu(xk2 @ lp["cm_k"].astype(cfg.dtype)))
    rr = jax.nn.sigmoid(xr2 @ lp["cm_r"].astype(cfg.dtype))
    x1 = x1 + rr * (kk @ lp["cm_v"].astype(cfg.dtype))
    return x1, wkv_new, h, h2


def decode_step(params, token, state: RwkvState, cfg: ArchConfig):
    """token: i32[B] -> (logits f32[B, V], new state)."""
    x = C.embed_tokens(params["embed"], token[:, None], cfg)[:, 0]

    def scan_fn(xx, inp):
        lp, wkv_s, tm_p, cm_p = inp
        xx, wkv_new, tm_new, cm_new = _layer_step(lp, xx, wkv_s, tm_p, cm_p,
                                                  cfg)
        return xx, (wkv_new, tm_new, cm_new)

    x, (wkv_new, tm_new, cm_new) = jax.lax.scan(
        scan_fn, x, (params["blocks"], state.wkv, state.tm_prev,
                     state.cm_prev), unroll=cfg.scan_unroll)
    logits = C.lm_head(params["embed"], x[:, None], cfg)[:, 0]
    return logits, RwkvState(wkv_new, tm_new, cm_new, state.pos + 1)


def prefill(params, tokens, cfg: ArchConfig, max_len: int = 0):
    """Prefill via the chunked WKV, returning the decode state."""
    B, S = tokens.shape
    x = C.embed_tokens(params["embed"], tokens, cfg)
    L = cfg.n_layers
    H, hd, D = cfg.n_heads, cfg.hd, cfg.d_model

    def scan_fn(xx, lp):
        h = C.rmsnorm(xx, lp["ln1"])
        sx = _shift(h)
        mu = lp["mu"].astype(cfg.dtype)
        xr, xk, xv, xw, xg = (h + mu[i] * (sx - h) for i in range(5))
        r = jnp.einsum("bsd,de->bse", xr, lp["wr"].astype(cfg.dtype))
        k = jnp.einsum("bsd,de->bse", xk, lp["wk"].astype(cfg.dtype))
        v = jnp.einsum("bsd,de->bse", xv, lp["wv"].astype(cfg.dtype))
        g = jax.nn.silu(jnp.einsum("bsd,de->bse", xg,
                                   lp["wg"].astype(cfg.dtype)))
        w = _decay(lp, xw, cfg)
        u = lp["u"].astype(jnp.float32).reshape(H, hd)
        y, s_fin = wkv_ops.wkv_chunked(
            r.reshape(B, S, H, hd), k.reshape(B, S, H, hd),
            v.reshape(B, S, H, hd), w.reshape(B, S, H, hd), u)
        y = C.rmsnorm(y.reshape(B, S, D), lp["ln_x"])
        xx = xx + jnp.einsum("bsd,de->bse", (y * g).astype(cfg.dtype),
                             lp["wo"].astype(cfg.dtype))
        tm_prev = h[:, -1]
        h2 = C.rmsnorm(xx, lp["ln2"])
        xx = xx + _channel_mix_tail(lp, h2, cfg)
        return xx, (s_fin, tm_prev, h2[:, -1])

    def _channel_mix_tail(lp, h2, cfg):
        sx = _shift(h2)
        cmu = lp["cm_mu"].astype(cfg.dtype)
        xk2 = h2 + cmu[0] * (sx - h2)
        xr2 = h2 + cmu[1] * (sx - h2)
        kk = jnp.square(jax.nn.relu(
            jnp.einsum("bsd,df->bsf", xk2, lp["cm_k"].astype(cfg.dtype))))
        rr = jax.nn.sigmoid(jnp.einsum("bsd,de->bse", xr2,
                                       lp["cm_r"].astype(cfg.dtype)))
        return rr * jnp.einsum("bsf,fd->bsd", kk,
                               lp["cm_v"].astype(cfg.dtype))

    x, (wkv_s, tm_prev, cm_prev) = jax.lax.scan(scan_fn, x, params["blocks"],
                                                unroll=cfg.scan_unroll)
    logits = C.lm_head(params["embed"], x[:, -1:], cfg)[:, 0]
    return logits, RwkvState(wkv_s, tm_prev, cm_prev, jnp.int32(S))
