"""Vision-language model (InternVL2-1B backbone: InternViT + Qwen2-0.5B-ish
LM). Per the assignment, the modality frontend is a STUB — ``input_specs``
provides precomputed patch embeddings [B, n_patches, d_model] (the InternViT
tower + MLP projector output); the LM backbone is real and shares the
decoder-only transformer implementation (QKV bias per Qwen2 lineage).

Training computes next-token loss on the text positions only (the patch
prefix is context). Serving prefills [patches; prompt] then decodes text.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.models import transformer as tfm
from repro.models.common import ArchConfig
from repro.models import common as C


init = tfm.init
init_cache = tfm.init_cache


def forward(params, tokens, cfg: ArchConfig, patches=None, **_):
    """tokens: i32[B, S_text]; patches: f32[B, P, D]."""
    return tfm.forward(params, tokens, cfg, inputs_embeds=patches)


def prefill(params, tokens, cfg: ArchConfig, max_len: int, patches=None):
    """Prefill patches+prompt. Cache covers the concatenated sequence."""
    x_patch = patches.astype(cfg.dtype)
    x_tok = C.embed_tokens(params["embed"], tokens, cfg)
    x = jnp.concatenate([x_patch, x_tok], axis=1)

    import jax

    def scan_fn(xx, bp):
        xx, caches = tfm._block_prefill(bp, xx, cfg, max_len)
        return xx, caches

    x, caches = jax.lax.scan(scan_fn, x, params["blocks"])
    logits = C.lm_head(params["embed"], x[:, -1:], cfg)[:, 0]
    pos = jnp.int32(x_patch.shape[1] + tokens.shape[1])
    return logits, tfm.DecodeState(caches, pos)


decode_step = tfm.decode_step
