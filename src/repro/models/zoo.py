"""Model zoo dispatch: one uniform functional API over all families.

    api = get_api(cfg)
    params_ann = api.init(key)                      # Annotated (axes) tree
    logits = api.forward(params, batch)
    loss = api.loss(params, batch)
    logits, state = api.prefill(params, batch, max_len)
    logits, state = api.decode(params, tokens, state)
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict

import jax
import jax.numpy as jnp

from repro.models import encdec, mamba2, rwkv6, transformer, vlm
from repro.models.common import ArchConfig
from repro.models.loss import next_token_loss

_FAMILY_MODULE = {
    "dense": transformer,
    "moe": transformer,
    "ssm": rwkv6,
    "hybrid": mamba2,
    "encdec": encdec,
    "vlm": vlm,
}


@dataclass
class ModelAPI:
    cfg: ArchConfig
    mod: Any

    def init(self, key):
        return self.mod.init(key, self.cfg)

    def _extras(self, batch: Dict[str, jnp.ndarray]) -> Dict[str, Any]:
        ex = {}
        if "frames" in batch:
            ex["frames"] = batch["frames"]
        if "patches" in batch:
            ex["patches"] = batch["patches"]
        return ex

    def forward(self, params, batch):
        return self.mod.forward(params, batch["tokens"], self.cfg,
                                **self._extras(batch))

    def loss(self, params, batch):
        logits = self.forward(params, batch)
        return next_token_loss(logits, batch["tokens"])

    def prefill(self, params, batch, max_len: int):
        return self.mod.prefill(params, batch["tokens"], self.cfg, max_len,
                                **self._extras(batch))

    def decode(self, params, tokens, state):
        return self.mod.decode_step(params, tokens, state, self.cfg)

    def init_cache(self, batch: int, max_len: int, pos: int | None = None):
        """Full decode state with the cache sized ``max_len`` and the write
        position at ``pos`` (default: cache almost full — the steady-state
        decode step the decode_* shapes specify)."""
        pos = max_len - 1 if pos is None else pos
        cfg = self.cfg
        if cfg.family in ("dense", "moe", "vlm"):
            caches = transformer.init_cache(cfg, batch, max_len)
            return transformer.DecodeState(caches, jnp.int32(pos))
        if cfg.family == "encdec":
            return encdec.make_decode_state(cfg, batch, max_len, pos)
        state = self.mod.init_cache(cfg, batch, max_len)
        return state._replace(pos=jnp.int32(pos))

    @property
    def has_decoder(self) -> bool:
        return True  # all assigned archs have a decode path


def get_api(cfg: ArchConfig) -> ModelAPI:
    return ModelAPI(cfg, _FAMILY_MODULE[cfg.family])
