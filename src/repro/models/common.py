"""Shared model-zoo plumbing: arch config, norms, RoPE, embeddings, init.

All models are functional: ``init(cfg, key) -> params`` pytrees and pure
forward functions. Layer parameters are *stacked* along a leading layer axis
and bodies run under ``lax.scan`` so the lowered HLO stays small (critical
for 512-device dry-run compiles) and remat policies apply uniformly.

Logical sharding axes are attached to every parameter via
``jax.sharding.PartitionSpec``-compatible *logical names* resolved by
repro.parallel.sharding (DP/FSDP/TP/EP rules).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


# ---------------------------------------------------------------------------
# Architecture config (one per assigned arch; see repro.configs).
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str               # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0         # 0 -> d_model // n_heads
    # attention
    rope_theta: float = 1.0e6
    sliding_window: int = 0   # 0 = full causal attention
    qkv_bias: bool = False
    # MoE
    n_experts: int = 0
    top_k: int = 0
    moe_every: int = 1        # every k-th layer is MoE (llama4 interleaves)
    capacity_factor: float = 1.25
    moe_group: int = 1024     # router group size (tokens)
    # SSM (rwkv6 / mamba2)
    ssm_state: int = 0
    ssm_heads: int = 0
    conv_kernel: int = 4
    # TP head padding (beyond-paper optimization):
    # pad q heads to this count (0 = off) so attention shards over the
    # 16-way model axis when the spec head count doesn't divide it. Padded
    # wo rows are zero-initialized, so the padded model computes exactly the
    # same function at init; kv heads pad to ceil(h_pad / group).
    pad_heads_to: int = 0
    # hybrid (zamba2): a shared attention block every k SSM layers
    shared_attn_every: int = 0
    # encoder-decoder
    n_enc_layers: int = 0
    # modality frontend stub (vlm/audio): precomputed embeddings
    frontend: str = "none"    # none | vit | audio
    frontend_tokens: int = 256
    # numerics / training
    dtype: Any = jnp.bfloat16        # activation/compute dtype
    param_dtype: Any = jnp.float32   # parameter storage dtype
    moment_dtype: Any = jnp.float32  # optimizer moment dtype
    remat: str = "full"              # none | full | dots
    # scan-over-layers unroll factor. 1 = rolled (fast compile; XLA cost
    # analysis counts the body once). The dry-run roofline pass lowers with
    # full unroll so HLO_FLOPs/bytes are exact.
    scan_unroll: int | bool = 1
    # which shapes are meaningful for this arch (None = all)
    skip_shapes: Tuple[str, ...] = ()

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def h_pad(self) -> int:
        """Padded q-head count used by attention weights/compute."""
        return max(self.pad_heads_to, self.n_heads) or self.n_heads

    @property
    def kv_pad(self) -> int:
        """Padded kv-head count: ceil(h_pad / group); real heads keep their
        original kv mapping (head h -> kv h // G)."""
        g = max(self.n_heads // max(self.n_kv_heads, 1), 1)
        return -(-self.h_pad // g)

    def is_moe_layer(self, i: int) -> bool:
        return self.n_experts > 0 and (i % self.moe_every == self.moe_every - 1)

    @property
    def param_count(self) -> int:
        """Analytic parameter count (for 6ND roofline math)."""
        D, F, V, hd = self.d_model, self.d_ff, self.vocab, self.hd
        H, KV = self.n_heads, self.n_kv_heads
        attn = D * (H * hd) + 2 * D * (KV * hd) + (H * hd) * D
        dense_mlp = 3 * D * F
        moe_mlp = self.n_experts * 3 * D * F + D * self.n_experts
        if self.family in ("ssm",):
            # rwkv6: 6 square projections (r,k,v,w,g,o) + channel mix (3.5x)
            per_layer = 6 * D * D + int(2 * D * F)
            return self.n_layers * per_layer + 2 * V * D
        if self.family == "hybrid":
            d_inner = 2 * D
            per_ssm = 2 * D * d_inner + d_inner * D + \
                d_inner * (2 * self.ssm_state)
            n_shared = self.n_layers // max(self.shared_attn_every, 1)
            shared = attn + dense_mlp
            return self.n_layers * per_ssm + shared + 2 * V * D + n_shared * 0
        n_moe = sum(1 for i in range(self.n_layers) if self.is_moe_layer(i))
        n_dense = self.n_layers - n_moe
        total = self.n_layers * attn + n_dense * dense_mlp + n_moe * moe_mlp
        enc = self.n_enc_layers * (attn + dense_mlp)
        dec_cross = self.n_enc_layers and self.n_layers * attn  # cross-attn
        return total + enc + (dec_cross or 0) + 2 * V * D

    @property
    def active_param_count(self) -> int:
        """Active params per token (MoE counts top_k experts)."""
        if self.n_experts == 0:
            return self.param_count
        D, F = self.d_model, self.d_ff
        moe_full = self.n_experts * 3 * D * F
        moe_active = max(self.top_k, 1) * 3 * D * F
        n_moe = sum(1 for i in range(self.n_layers) if self.is_moe_layer(i))
        return self.param_count - n_moe * (moe_full - moe_active)


# ---------------------------------------------------------------------------
# Logical-axis annotated parameters.
# ---------------------------------------------------------------------------
class Annotated:
    """Wrapper used only at init time: array + logical axis names.

    Registered as a pytree node (axes are static aux data) so ``vmap`` over
    layer init stacks values while keeping the per-layer logical axes; the
    extra leading 'layers' axis is reconciled in
    ``repro.parallel.sharding.spec_for`` (padded with None).
    """
    __slots__ = ("value", "axes")

    def __init__(self, value, axes):
        self.value = value
        self.axes = axes


jax.tree_util.register_pytree_node(
    Annotated,
    lambda a: ((a.value,), a.axes),
    lambda axes, children: Annotated(children[0], axes))


def param(key, shape, axes, dtype, scale: float | None = None,
          init: str = "normal"):
    """Initialize one parameter with logical axes metadata."""
    if init == "zeros":
        v = jnp.zeros(shape, dtype)
    elif init == "ones":
        v = jnp.ones(shape, dtype)
    else:
        fan_in = shape[0] if len(shape) > 1 else shape[-1]
        s = scale if scale is not None else 1.0 / np.sqrt(fan_in)
        v = jax.random.normal(key, shape, dtype) * s
    return Annotated(v, axes)


def split_tree(params):
    """Annotated tree -> (value tree, axes tree)."""
    vals = jax.tree_util.tree_map(
        lambda a: a.value, params, is_leaf=lambda x: isinstance(x, Annotated))
    axes = jax.tree_util.tree_map(
        lambda a: a.axes, params, is_leaf=lambda x: isinstance(x, Annotated))
    return vals, axes


# ---------------------------------------------------------------------------
# Layers.
# ---------------------------------------------------------------------------
def rmsnorm(x, scale, eps=1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    out = x * jax.lax.rsqrt(var + eps)
    return (out * (1.0 + scale.astype(jnp.float32))).astype(dt)


def rope_freqs(hd: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, hd, 2, dtype=jnp.float32) / hd))


def apply_rope(x, positions, theta: float):
    """x: [..., S, H, hd]; positions: broadcastable to [..., S]."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                      # [hd/2]
    ang = positions[..., :, None].astype(jnp.float32) * freqs  # [..., S, hd/2]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    cos = cos[..., :, None, :]                         # [..., S, 1, hd/2]
    sin = sin[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def embed_init(key, cfg: ArchConfig):
    k1, k2 = jax.random.split(key)
    return {
        "tok": param(k1, (cfg.vocab, cfg.d_model), ("vocab", "embed"),
                     cfg.param_dtype, scale=1.0),
        "out": param(k2, (cfg.d_model, cfg.vocab), ("embed", "vocab"),
                     cfg.param_dtype),
        "ln_f": param(k1, (cfg.d_model,), ("embed",), cfg.param_dtype,
                      init="zeros"),
    }


def embed_tokens(params, tokens, cfg: ArchConfig):
    return params["tok"][tokens].astype(cfg.dtype)


def lm_head(params, x, cfg: ArchConfig):
    x = rmsnorm(x, params["ln_f"])
    return jnp.einsum("...d,dv->...v", x,
                      params["out"].astype(cfg.dtype)).astype(jnp.float32)


def make_remat(fn, mode: str):
    if mode == "none":
        return fn
    if mode == "dots":
        policy = jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims
        return jax.checkpoint(fn, policy=policy)
    return jax.checkpoint(fn)  # full
