"""Feed-forward layers: dense SwiGLU and grouped top-k MoE (GShard-style
dispatch with capacity, einsum formulation).

MoE design: tokens are routed in *groups* of ``moe_group``
tokens so the dispatch/combine tensors stay VMEM/HBM-friendly:
[G, Sg, E, C] with C = ceil(top_k * Sg / E * capacity_factor). Expert
parallelism shards the expert axis over the ``model`` mesh axis when the
expert count divides it (llama4: 128 experts), and falls back to intra-expert
tensor parallelism (d_ff over ``model``) otherwise (mixtral: 8 experts).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import ArchConfig, param


# ---------------------------------------------------------------------------
# Dense SwiGLU.
# ---------------------------------------------------------------------------
def init_dense(key, cfg: ArchConfig):
    D, F = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 3)
    return {
        "w_gate": param(ks[0], (D, F), ("embed", "mlp"), cfg.param_dtype),
        "w_up": param(ks[1], (D, F), ("embed", "mlp"), cfg.param_dtype),
        "w_down": param(ks[2], (F, D), ("mlp", "embed"), cfg.param_dtype),
    }


def forward_dense(p, x, cfg: ArchConfig):
    g = jnp.einsum("bsd,df->bsf", x, p["w_gate"].astype(cfg.dtype))
    u = jnp.einsum("bsd,df->bsf", x, p["w_up"].astype(cfg.dtype))
    h = jax.nn.silu(g) * u
    return jnp.einsum("bsf,fd->bsd", h, p["w_down"].astype(cfg.dtype))


# ---------------------------------------------------------------------------
# Mixture of experts.
# ---------------------------------------------------------------------------
def init_moe(key, cfg: ArchConfig):
    D, F, E = cfg.d_model, cfg.d_ff, cfg.n_experts
    ks = jax.random.split(key, 4)
    return {
        "router": param(ks[0], (D, E), ("embed", "unsharded"),
                        cfg.param_dtype),
        "w_gate": param(ks[1], (E, D, F), ("expert", "embed", "mlp"),
                        cfg.param_dtype),
        "w_up": param(ks[2], (E, D, F), ("expert", "embed", "mlp"),
                      cfg.param_dtype),
        "w_down": param(ks[3], (E, F, D), ("expert", "mlp", "embed"),
                        cfg.param_dtype),
    }


def _capacity(cfg: ArchConfig, sg: int) -> int:
    c = int(cfg.top_k * sg * cfg.capacity_factor / cfg.n_experts) + 1
    return min(max(c, cfg.top_k), sg)


def route_topk(logits: jnp.ndarray, cfg: ArchConfig, capacity: int):
    """GShard-style dispatch. logits: [G, Sg, E].

    Returns (dispatch [G,Sg,E,C] one-hot, combine [G,Sg,E,C] gate-weighted).
    Position-in-expert is computed slot-major (all slot-0 assignments get
    positions before slot-1), matching the reference top-k routing.
    """
    G, Sg, E = logits.shape
    k = cfg.top_k
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, k)        # [G,Sg,k]
    # renormalize selected gates (mixtral-style)
    gate_vals = gate_vals / jnp.sum(gate_vals, -1, keepdims=True)

    onehot = jax.nn.one_hot(expert_idx, E, dtype=jnp.int32)  # [G,Sg,k,E]
    # slot-major position: transpose k before Sg, cumsum over (k, Sg) flat
    oh_km = onehot.transpose(0, 2, 1, 3).reshape(G, k * Sg, E)
    pos_flat = jnp.cumsum(oh_km, axis=1) - oh_km           # positions from 0
    pos = pos_flat.reshape(G, k, Sg, E).transpose(0, 2, 1, 3)  # [G,Sg,k,E]
    keep = (pos < capacity) & (onehot > 0)

    pos_oh = jax.nn.one_hot(pos, capacity, dtype=logits.dtype)  # [G,Sg,k,E,C]
    keepf = keep.astype(logits.dtype)[..., None]
    dispatch = jnp.sum(pos_oh * keepf * onehot[..., None].astype(logits.dtype),
                       axis=2)                              # [G,Sg,E,C]
    combine = jnp.sum(
        pos_oh * keepf * (gate_vals[..., None, None] *
                          onehot[..., None].astype(logits.dtype)), axis=2)
    return dispatch, combine


def forward_moe(p, x, cfg: ArchConfig):
    """x: [B, S, D] -> [B, S, D]."""
    B, S, D = x.shape
    tokens = x.reshape(B * S, D)
    sg = min(cfg.moe_group, B * S)
    # pad to a whole number of groups
    n_tok = tokens.shape[0]
    n_groups = -(-n_tok // sg)
    pad = n_groups * sg - n_tok
    if pad:
        tokens = jnp.pad(tokens, ((0, pad), (0, 0)))
    xg = tokens.reshape(n_groups, sg, D)

    logits = jnp.einsum("gsd,de->gse", xg, p["router"].astype(cfg.dtype))
    capacity = _capacity(cfg, sg)
    dispatch, combine = route_topk(logits, cfg, capacity)
    dispatch = dispatch.astype(cfg.dtype)
    combine = combine.astype(cfg.dtype)

    # dispatch tokens to expert buffers: [E, G, C, D]
    xe = jnp.einsum("gsec,gsd->egcd", dispatch, xg)
    g = jnp.einsum("egcd,edf->egcf", xe, p["w_gate"].astype(cfg.dtype))
    u = jnp.einsum("egcd,edf->egcf", xe, p["w_up"].astype(cfg.dtype))
    h = jax.nn.silu(g) * u
    ye = jnp.einsum("egcf,efd->egcd", h, p["w_down"].astype(cfg.dtype))
    out = jnp.einsum("gsec,egcd->gsd", combine, ye)

    out = out.reshape(n_groups * sg, D)
    if pad:
        out = out[:n_tok]
    return out.reshape(B, S, D)


def aux_load_balance_loss(logits: jnp.ndarray, cfg: ArchConfig) -> jnp.ndarray:
    """Switch-style load-balancing auxiliary loss over router logits."""
    probs = jax.nn.softmax(logits.astype(jnp.float32), -1)
    frac_probs = probs.mean(axis=tuple(range(probs.ndim - 1)))
    top1 = jnp.argmax(probs, -1)
    frac_tokens = jnp.mean(
        jax.nn.one_hot(top1, cfg.n_experts, dtype=jnp.float32),
        axis=tuple(range(probs.ndim - 1)))
    return cfg.n_experts * jnp.sum(frac_probs * frac_tokens)
