"""Grouped-query attention with RoPE, optional sliding window (Mixtral),
optional QKV bias (Qwen2.5), and a KV cache for serving.

Default path is pure-jnp einsum attention (fuses well under XLA and lowers
on every backend, which the 512-device dry-run requires). On TPU runtime the
Pallas flash kernel (repro.kernels.flash_attention) can be swapped in via
``use_flash``; both are validated against each other in tests.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.models import common as C
from repro.models.common import ArchConfig, param


class KVCache(NamedTuple):
    k: jnp.ndarray       # [B, Smax, KV, hd]
    v: jnp.ndarray       # [B, Smax, KV, hd]


def init(key, cfg: ArchConfig, layer_prefix: str = ""):
    """Weights use the *padded* head counts (cfg.h_pad / cfg.kv_pad); wo
    rows for padded heads are zeroed so the padded model computes exactly
    the spec model's function at init."""
    hd, H, KV, D = cfg.hd, cfg.h_pad, cfg.kv_pad, cfg.d_model
    ks = jax.random.split(key, 5)
    wo = param(ks[3], (H, hd, D), ("heads", "head_dim", "embed"),
               cfg.param_dtype)
    if H > cfg.n_heads:
        wo.value = wo.value.at[cfg.n_heads:].set(0.0)
    p = {
        "wq": param(ks[0], (D, H, hd), ("embed", "heads", "head_dim"),
                    cfg.param_dtype),
        "wk": param(ks[1], (D, KV, hd), ("embed", "kv_heads", "head_dim"),
                    cfg.param_dtype),
        "wv": param(ks[2], (D, KV, hd), ("embed", "kv_heads", "head_dim"),
                    cfg.param_dtype),
        "wo": wo,
    }
    if cfg.qkv_bias:
        p["bq"] = param(ks[4], (H, hd), ("heads", "head_dim"),
                        cfg.param_dtype, init="zeros")
        p["bk"] = param(ks[4], (KV, hd), ("kv_heads", "head_dim"),
                        cfg.param_dtype, init="zeros")
        p["bv"] = param(ks[4], (KV, hd), ("kv_heads", "head_dim"),
                        cfg.param_dtype, init="zeros")
    return p


def _qkv(p, x, cfg: ArchConfig, positions):
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(cfg.dtype))
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"].astype(cfg.dtype))
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"].astype(cfg.dtype))
    if "bq" in p:
        q = q + p["bq"].astype(cfg.dtype)
        k = k + p["bk"].astype(cfg.dtype)
        v = v + p["bv"].astype(cfg.dtype)
    q = C.apply_rope(q, positions, cfg.rope_theta)
    k = C.apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def _sdpa(q, k, v, mask, cfg: ArchConfig):
    """q: [B,S,H,hd]; k/v: [B,T,KV,hd]; mask broadcastable to [B,H,S,T].

    GQA via per-head kv gather (head h uses kv h // G): the Megatron-style
    TP formulation. The naive grouped reshape [B,S,H,hd]->[B,S,KV,G,hd]
    *breaks* the head sharding whenever KV doesn't divide the model axis
    (XLA reshards and replicates the quadratic attention) — measured 5-13x
    redundant compute before this change.
    The gather keeps q/logits/out sharded by H end-to-end; for MHA it is an
    identity gather that XLA elides.
    """
    B, S, H, hd = q.shape
    KV = k.shape[2]
    g_spec = max(cfg.n_heads // max(cfg.n_kv_heads, 1), 1)
    if g_spec == 1 and KV == H:
        # MHA: skip the identity gather — XLA does not recognize it on a
        # model-sharded kv cache and would all-gather ~100 GB per decode
        # step (avoids a per-step gather)
        kh, vh = k, v
    else:
        head_kv = jnp.arange(H) // g_spec       # [H]
        kh = jnp.take(k, head_kv, axis=2)       # [B,T,H,hd]
        vh = jnp.take(v, head_kv, axis=2)
    logits = jnp.einsum("bshd,bthd->bhst", q, kh).astype(jnp.float32)
    logits = logits / jnp.sqrt(hd).astype(jnp.float32)
    m = mask
    while m.ndim > 4:
        m = m.squeeze(1)
    logits = jnp.where(m, logits, -1e30)
    w = jax.nn.softmax(logits, axis=-1).astype(cfg.dtype)
    return jnp.einsum("bhst,bthd->bshd", w, vh)


def causal_mask(S: int, T: int, window: int = 0, offset: int = 0):
    """[S, T] bool; query i attends key j iff j <= i+offset (and within the
    sliding window when window > 0)."""
    qi = jnp.arange(S)[:, None] + offset
    kj = jnp.arange(T)[None, :]
    m = kj <= qi
    if window > 0:
        m &= kj > (qi - window)
    return m


def forward_train(p, x, cfg: ArchConfig, bidirectional: bool = False):
    B, S, D = x.shape
    positions = jnp.arange(S)[None, :]
    q, k, v = _qkv(p, x, cfg, positions)
    if bidirectional:
        mask = jnp.ones((S, S), bool)
    else:
        mask = causal_mask(S, S, cfg.sliding_window)
    out = _sdpa(q, k, v, mask[None, None], cfg)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(cfg.dtype))


def forward_cross(p, x, kv_src, cfg: ArchConfig):
    """Cross attention (enc-dec): queries from x, keys/values from kv_src."""
    B, S, D = x.shape
    T = kv_src.shape[1]
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(cfg.dtype))
    k = jnp.einsum("bsd,dhk->bshk", kv_src, p["wk"].astype(cfg.dtype))
    v = jnp.einsum("bsd,dhk->bshk", kv_src, p["wv"].astype(cfg.dtype))
    mask = jnp.ones((S, T), bool)
    out = _sdpa(q, k, v, mask[None, None], cfg)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(cfg.dtype))


# ---------------------------------------------------------------------------
# Serving path.
# ---------------------------------------------------------------------------
def init_cache(cfg: ArchConfig, batch: int, max_len: int,
               dtype=None) -> KVCache:
    dt = dtype or cfg.dtype
    shape = (batch, max_len, cfg.kv_pad, cfg.hd)
    return KVCache(jnp.zeros(shape, dt), jnp.zeros(shape, dt))


def forward_prefill(p, x, cfg: ArchConfig, max_len: int):
    """Prefill S tokens; returns (out, cache padded to max_len)."""
    B, S, D = x.shape
    positions = jnp.arange(S)[None, :]
    q, k, v = _qkv(p, x, cfg, positions)
    mask = causal_mask(S, S, cfg.sliding_window)
    out = _sdpa(q, k, v, mask[None, None], cfg)
    out = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(cfg.dtype))
    pad = max_len - S
    cache = KVCache(
        jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0))),
        jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0))))
    return out, cache


def forward_decode(p, x, cache: KVCache, pos: jnp.ndarray, cfg: ArchConfig):
    """One-token decode. x: [B, 1, D]; pos: [] current position (same for the
    whole batch — standard static-shape serving). Returns (out, new_cache)."""
    B = x.shape[0]
    positions = jnp.full((B, 1), pos, jnp.int32)
    q, k, v = _qkv(p, x, cfg, positions)
    k_cache = jax.lax.dynamic_update_slice_in_dim(cache.k, k, pos, axis=1)
    v_cache = jax.lax.dynamic_update_slice_in_dim(cache.v, v, pos, axis=1)
    T = k_cache.shape[1]
    kj = jnp.arange(T)[None, :]
    m = kj <= pos
    if cfg.sliding_window > 0:
        m &= kj > (pos - cfg.sliding_window)
    out = _sdpa(q, k_cache, v_cache, m[:, None, None, :], cfg)
    out = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(cfg.dtype))
    return out, KVCache(k_cache, v_cache)
