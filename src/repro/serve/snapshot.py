"""Byte-faithful snapshot codec for the scan carry (checkpoint/fork wire).

A *snapshot* is the serialized form of one branch's scan carry — the
complete ``SimState`` pytree (job lifecycle arrays, node occupancy,
account ledgers, the transient ``CoolingState``, global accumulators and
the absolute step cursor). Resuming a simulation from a decoded snapshot
is bit-identical to never having stopped (``engine.simulate_segment``;
proven by tests/test_serve_checkpoint.py), so a snapshot is both the
server's checkpoint format and the client-visible "download this branch"
payload.

Encoding: every leaf becomes ``{"dtype": "<f4", "shape": [...],
"data": "<base64 raw bytes>"}`` keyed by its pytree path (e.g.
``"accounts.energy"``). Raw bytes — not JSON floats — because JSON
number round-trips are not bit-faithful for float32 and a checkpoint
that perturbs the last ulp is not a checkpoint. Envelopes are strict
JSON and ride the PR 5 NDJSON transport framing unchanged
(``core.transport.write_frame``), staying far below ``MAX_FRAME_BYTES``
even at Frontier scale (tests/test_serve_properties.py measures it).

The scenario codec here is the *wire* form of ``types.Scenario``: plain
floats/lists per knob, so a fork request can carry a sparse delta
(``{"setpoint_delta_c": 2.0}``) that ``apply_scenario_delta`` merges
over the parent branch's knobs.
"""
from __future__ import annotations

import base64
import hashlib
import json

import jax
import numpy as np
import jax.numpy as jnp

from repro.core import types as T

SNAPSHOT_VERSION = 1

# Scenario knobs a fork delta may touch (every traced field; policy and
# backfill accept the names from types.POLICY_NAMES / BACKFILL_NAMES).
SCENARIO_FIELDS = tuple(f.name for f in
                        __import__("dataclasses").fields(T.Scenario))


class SnapshotError(ValueError):
    """A snapshot payload is malformed or does not match the template."""


# ---------------------------------------------------------------------------
# Pytree paths.
# ---------------------------------------------------------------------------
def _path_str(path) -> str:
    """Render a jax keypath as a dotted field path ("accounts.energy")."""
    parts = []
    for entry in path:
        name = getattr(entry, "name", None)
        if name is None:
            name = getattr(entry, "key", None)
        if name is None:
            name = getattr(entry, "idx", None)
        parts.append(str(name))
    return ".".join(parts)


def _flatten(carry):
    """(path string, leaf) pairs in canonical pytree order + treedef."""
    leaves, treedef = jax.tree_util.tree_flatten_with_path(carry)
    return [(_path_str(p), leaf) for p, leaf in leaves], treedef


# ---------------------------------------------------------------------------
# Array leaf codec (raw little-endian bytes, base64).
# ---------------------------------------------------------------------------
def encode_array(x, binary: bool = False):
    """One leaf → ``{"dtype", "shape", "data"}`` with base64 raw bytes.

    ``binary=True`` returns the host ndarray itself instead: riding the
    RBW1 binary frame dialect (``core.transport``), the transport ships
    its raw little-endian bytes directly — same values, no base64+JSON
    expansion (~1.33x bytes + encode/decode CPU) on Frontier-scale
    snapshots."""
    # NOT ascontiguousarray: that promotes 0-d arrays to 1-d, and
    # tobytes() below makes its own C-order copy anyway
    a = np.asarray(x)
    if a.dtype.byteorder == ">":  # pragma: no cover - big-endian host
        a = a.astype(a.dtype.newbyteorder("<"))
    if binary:
        return a
    return {"dtype": a.dtype.str, "shape": list(a.shape),
            "data": base64.b64encode(a.tobytes()).decode("ascii")}


def decode_array(payload) -> np.ndarray:
    """Inverse of ``encode_array``; validates dtype/shape/size.

    Accepts both spellings: the base64 dict, and a bare ndarray (what
    ``transport.read_any_frame`` hands back for a binary-dialect leaf)."""
    if isinstance(payload, np.ndarray):
        return payload
    if not isinstance(payload, dict):
        raise SnapshotError(f"leaf must be an object, got "
                            f"{type(payload).__name__}")
    try:
        dtype = np.dtype(payload["dtype"])
        shape = tuple(int(s) for s in payload["shape"])
        raw = base64.b64decode(payload["data"], validate=True)
    except (KeyError, TypeError, ValueError) as e:
        raise SnapshotError(f"malformed array leaf: {e}") from e
    want = dtype.itemsize * int(np.prod(shape, dtype=np.int64)) \
        if shape else dtype.itemsize
    if len(raw) != want:
        raise SnapshotError(f"array leaf carries {len(raw)} bytes, "
                            f"dtype/shape imply {want}")
    return np.frombuffer(raw, dtype=dtype).reshape(shape).copy()


# ---------------------------------------------------------------------------
# Carry codec.
# ---------------------------------------------------------------------------
def encode_carry(carry: T.SimState, binary: bool = False) -> dict:
    """Serialize a scan carry to a strict-JSON payload.

    The payload is self-describing (``v``, per-leaf dtype/shape) but
    decoding requires a structural *template* (any carry of the same
    (system, table) lineage — ``engine.init_state`` builds one) because
    the pytree treedef itself is not serialized.

    ``binary=True`` produces the raw-array dialect (leaves are host
    ndarrays, for RBW1 binary frames); ``carry_digest`` is the digest
    that is stable across both dialects.
    """
    leaves, _ = _flatten(carry)
    return {"v": SNAPSHOT_VERSION,
            "leaves": {path: encode_array(leaf, binary=binary)
                       for path, leaf in leaves}}


def decode_carry(payload: dict, template: T.SimState) -> T.SimState:
    """Rebuild a carry from ``encode_carry`` output, byte-faithfully.

    ``template`` supplies the pytree structure; every leaf's dtype and
    shape must match the template's (a snapshot from a different system
    or job-table shape fails loudly instead of mis-resuming).
    """
    if not isinstance(payload, dict):
        raise SnapshotError(f"snapshot must be an object, got "
                            f"{type(payload).__name__}")
    if payload.get("v") != SNAPSHOT_VERSION:
        raise SnapshotError(f"snapshot version mismatch: "
                            f"{payload.get('v')!r} != {SNAPSHOT_VERSION}")
    leaves = payload.get("leaves")
    if not isinstance(leaves, dict):
        raise SnapshotError("snapshot missing 'leaves' object")
    t_leaves, treedef = _flatten(template)
    missing = [p for p, _ in t_leaves if p not in leaves]
    extra = [p for p in leaves if p not in {q for q, _ in t_leaves}]
    if missing or extra:
        raise SnapshotError(
            f"snapshot leaves do not match the template: "
            f"missing {missing or '[]'}, unknown {extra or '[]'}")
    out = []
    for path, ref in t_leaves:
        a = decode_array(leaves[path])
        ref = np.asarray(ref)
        if a.dtype != ref.dtype or a.shape != ref.shape:
            raise SnapshotError(
                f"leaf {path!r}: snapshot is {a.dtype}{list(a.shape)}, "
                f"template needs {ref.dtype}{list(ref.shape)}")
        out.append(jnp.asarray(a))
    return jax.tree_util.tree_unflatten(treedef, out)


def snapshot_digest(payload: dict) -> str:
    """sha256 over the canonical JSON of a snapshot payload.

    Stable across processes/hosts (sorted keys, no whitespace), so a
    client can verify a download and the parity tests can assert two
    encodes of the same carry are byte-identical. Only defined for the
    base64 (JSON) dialect — for digests that hold across dialects use
    ``carry_digest``."""
    blob = json.dumps(payload, separators=(",", ":"), sort_keys=True)
    return hashlib.sha256(blob.encode("utf-8")).hexdigest()


def carry_digest(payload: dict) -> str:
    """Dialect-independent sha256 over a snapshot's *content*.

    Hashes (path, dtype, shape, raw little-endian bytes) per leaf in
    sorted path order — the same carry produces the same digest whether
    it was encoded as base64 JSON or as raw binary-frame arrays, so a
    client that downloaded over one dialect can verify against a server
    that re-encoded over the other."""
    leaves = payload.get("leaves") if isinstance(payload, dict) else None
    if not isinstance(leaves, dict):
        raise SnapshotError("snapshot missing 'leaves' object")
    h = hashlib.sha256()
    h.update(b"carry-digest-v%d" % SNAPSHOT_VERSION)
    for path in sorted(leaves):
        a = decode_array(leaves[path])
        if a.dtype.byteorder == ">":  # pragma: no cover - big-endian host
            a = a.astype(a.dtype.newbyteorder("<"))
        h.update(path.encode("utf-8"))
        h.update(a.dtype.str.encode("ascii"))
        h.update(json.dumps(list(a.shape)).encode("ascii"))
        h.update(np.ascontiguousarray(a).tobytes())
    return h.hexdigest()


# ---------------------------------------------------------------------------
# Scenario wire codec.
# ---------------------------------------------------------------------------
def encode_scenario(scen: T.Scenario) -> dict:
    """Scenario → plain floats/ints/lists (the fork-request wire form)."""
    out = {}
    for name in SCENARIO_FIELDS:
        a = np.asarray(getattr(scen, name))
        if name in ("policy", "backfill"):
            out[name] = int(a)
        else:
            out[name] = a.tolist() if a.ndim else float(a)
    return out


def apply_scenario_delta(parent: T.Scenario, delta: dict) -> T.Scenario:
    """Merge a sparse knob delta over a parent branch's scenario.

    ``delta`` keys must be Scenario fields; ``policy``/``backfill``
    accept wire names ("fcfs", "easy") or raw ints, every other knob a
    number or list (``cells_offline`` per hall, ``alpha`` vector). An
    empty delta returns a scenario equal to the parent — the *neutral
    fork* whose branch must stay bit-identical to its parent
    (tests/test_serve_checkpoint.py).

    Every merged leaf must keep the **parent's shape**: coalesced sweeps
    stack branch scenarios leaf-wise, so a fork that reshaped a knob
    (vector where the session uses a scalar, or the wrong vector length)
    would blow up as a JAX trace error *inside the server's executor*,
    on behalf of every batched client. That failure is rejected here, at
    fork time, as a ``SnapshotError`` the requester alone pays for. A
    scalar delta on a vector knob is broadcast explicitly.
    """
    if not isinstance(delta, dict):
        raise SnapshotError(f"scenario delta must be an object, got "
                            f"{type(delta).__name__}")
    unknown = sorted(set(delta) - set(SCENARIO_FIELDS))
    if unknown:
        raise SnapshotError(f"unknown scenario knob(s): "
                            f"{', '.join(unknown)}; valid: "
                            f"{', '.join(SCENARIO_FIELDS)}")
    merged = encode_scenario(parent)
    for k, v in delta.items():
        if k in ("policy", "backfill"):
            names = T.POLICY_NAMES if k == "policy" else T.BACKFILL_NAMES
            if isinstance(v, str):
                if v not in names:
                    raise SnapshotError(f"unknown {k} {v!r}")
                v = names[v]
            elif not isinstance(v, int) or isinstance(v, bool) or \
                    v not in names.values():
                raise SnapshotError(f"{k} must be a name or known id, "
                                    f"got {v!r}")
            merged[k] = int(v)
        else:
            ok_num = isinstance(v, (int, float)) and not isinstance(v, bool)
            ok_vec = (isinstance(v, list) and v and
                      all(isinstance(x, (int, float)) and
                          not isinstance(x, bool) for x in v))
            if not (ok_num or ok_vec):
                raise SnapshotError(f"scenario knob {k!r} must be a "
                                    f"number or list of numbers, got {v!r}")
            ref = np.asarray(getattr(parent, k))
            if ok_vec:
                if ref.ndim == 0:
                    raise SnapshotError(
                        f"scenario knob {k!r} is a scalar in this "
                        f"session; a {len(v)}-element vector would "
                        f"change the traced leaf shape")
                if len(v) != int(ref.shape[0]):
                    raise SnapshotError(
                        f"scenario knob {k!r} must have length "
                        f"{int(ref.shape[0])} in this session, got "
                        f"{len(v)}")
                merged[k] = [float(x) for x in v]
            elif ref.ndim:
                # scalar onto a vector knob: broadcast explicitly so the
                # child's leaf keeps the parent's shape
                merged[k] = [float(v)] * int(ref.shape[0])
            else:
                merged[k] = v
    return T.Scenario(
        policy=jnp.int32(merged["policy"]),
        backfill=jnp.int32(merged["backfill"]),
        **{k: jnp.asarray(merged[k], jnp.float32)
           for k in SCENARIO_FIELDS if k not in ("policy", "backfill")})
