"""The persistent twin server: sockets in, coalesced sweeps out.

``TwinServer`` listens on a Unix-domain or TCP socket
(``core.transport.parse_address`` syntax), greets every accepted client
with a ``hello`` frame (repro.serve.protocol) and serves requests
against one shared ``TwinSession``.

Concurrency shape — the part that makes this a *service* and not a
socket wrapper:

* one **accept thread** takes connections and starts a handler thread
  per client;
* handler threads parse/validate and answer cheap requests (fork,
  snapshot, fetch, state) inline under the session lock;
* **advance** requests are enqueued to a single **executor thread**
  that waits ``batch_window_s`` for stragglers, then drains the queue
  and dispatches ALL pending branches as one
  ``engine.simulate_segment_sweep`` batch per interval tick
  (``TwinSession.advance_many``). Concurrent clients advancing
  divergent forks therefore cost one compiled program per tick, not one
  per client — and the batched result is bitwise identical to serial
  execution (tests/test_serve_soak.py).

Failure model (inherited from the PR 5 wire): a client that dies
mid-stream surfaces as ``ConnectionError`` and only its handler exits; a
client speaking garbage gets a ``protocol`` error envelope and its
connection closed; a well-formed but invalid request (unknown branch,
bad knob) gets a ``session`` error envelope and the connection stays.
The server thread population never crashes on client behavior.

Zero-zombie ledger: every accepted connection is appended to
``clients`` and *never removed* (mirroring ``SubprocessPeer.spawned``);
``close()`` joins every handler and asserts nothing is left running, and
the soak test asserts the ledger is fully closed after each scenario.

Observability: with ``obs_dir`` set, the server writes a per-session run
manifest + NDJSON event log (repro.obs.recorder) — client connects/
disconnects, advance batches, forks and errors all land in the event
log, and ``finalize`` embeds the wire + session counters.
"""
from __future__ import annotations

import pathlib
import socket
import threading
import time
from dataclasses import dataclass, field
from typing import IO, List, Optional

from repro.core import transport as tr
from repro.core.external import ProtocolError
from repro.launch import env as launch_env
from repro.serve import protocol as proto
from repro.serve.session import SessionError, TwinSession


@dataclass
class _Client:
    """Ledger row for one accepted connection (never removed)."""
    client_id: int
    sock: socket.socket
    thread: Optional[threading.Thread] = None
    counters: tr.WireCounters = field(default_factory=tr.WireCounters)
    open: bool = True
    reason: str = ""          # why the connection ended ("bye", "eof", ...)


@dataclass
class _Pending:
    """One queued advance request awaiting the coalescing executor."""
    branch: int
    intervals: int
    done: threading.Event = field(default_factory=threading.Event)
    result: Optional[dict] = None
    error: Optional[Exception] = None


class TwinServer:
    """Serve one ``TwinSession`` to many clients over NDJSON frames."""

    def __init__(self, session: TwinSession, address: str, jobs=None,
                 batch_window_s: float = 0.01, obs_dir=None,
                 accept_timeout_s: float = 0.2,
                 client_timeout_s: float = 60.0):
        self.session = session
        self.jobs = jobs
        self.batch_window_s = float(batch_window_s)
        self.client_timeout_s = float(client_timeout_s)
        self.clients: List[_Client] = []
        self._clients_lock = threading.Lock()
        self._queue: List[_Pending] = []
        self._queue_cv = threading.Condition()
        self._shutdown = threading.Event()
        self.recorder = None
        if obs_dir is not None:
            from repro.obs.recorder import RunRecorder
            d = pathlib.Path(obs_dir)
            self.recorder = RunRecorder(
                manifest_path=d / "serve_manifest.json",
                events_path=d / "serve_events.ndjson")
            self.recorder.begin(
                session.system, command="serve", argv=[str(address)],
                scenario={"interval_steps": session.interval_steps,
                          "horizon_steps": session.horizon_steps},
                jobs=jobs,
                extra={"env_preset": launch_env.report("throughput")})

        family, sockaddr = tr.parse_address(str(address))
        self._listener = socket.socket(family, socket.SOCK_STREAM)
        if family == socket.AF_INET:
            self._listener.setsockopt(socket.SOL_SOCKET,
                                      socket.SO_REUSEADDR, 1)
        self._listener.bind(sockaddr)
        self._listener.listen(64)
        self._listener.settimeout(accept_timeout_s)
        self.address = tr.format_address(family,
                                         self._listener.getsockname())
        self._accept_thread = threading.Thread(
            target=self._accept_loop, name="twin-accept", daemon=True)
        self._exec_thread = threading.Thread(
            target=self._executor_loop, name="twin-executor", daemon=True)
        self._accept_thread.start()
        self._exec_thread.start()
        self._event("server_start", address=self.address)

    # -- observability -------------------------------------------------------
    def _event(self, what: str, **fields) -> None:
        if self.recorder is not None:
            self.recorder.event(what, **fields)

    # -- accept + per-client loops -------------------------------------------
    def _accept_loop(self) -> None:
        while not self._shutdown.is_set():
            try:
                conn, _ = self._listener.accept()
            except socket.timeout:
                continue
            except OSError:      # listener closed under us during shutdown
                break
            with self._clients_lock:
                client = _Client(client_id=len(self.clients), sock=conn)
                self.clients.append(client)
            client.thread = threading.Thread(
                target=self._client_loop, args=(client,),
                name=f"twin-client-{client.client_id}", daemon=True)
            client.thread.start()
            self._event("client_connect", client=client.client_id)

    def _client_loop(self, client: _Client) -> None:
        conn = client.sock
        conn.settimeout(self.client_timeout_s)
        rfile: IO[bytes] = conn.makefile("rb")
        wfile: IO[bytes] = conn.makefile("wb")
        try:
            tr.write_frame(wfile, proto.hello_frame(self.session,
                                                    self.jobs),
                           client.counters)
            while not self._shutdown.is_set():
                try:
                    msg = proto.validate_request(
                        tr.read_frame(rfile, client.counters))
                except ProtocolError as e:
                    # broken speech: answer, then hang up on this client
                    self._event("client_protocol_error",
                                client=client.client_id, message=str(e))
                    self._safe_write(wfile, client,
                                     proto.error_frame(None, e))
                    client.reason = "protocol-error"
                    return
                kind, msg_id = msg["kind"], msg.get("id")
                if kind == "bye":
                    self._safe_write(wfile, client,
                                     proto.ok_frame("bye", msg_id, {}))
                    client.reason = "bye"
                    return
                if kind == "shutdown":
                    self._safe_write(wfile, client,
                                     proto.ok_frame("shutdown", msg_id, {}))
                    client.reason = "shutdown"
                    self._shutdown.set()
                    with self._queue_cv:
                        self._queue_cv.notify_all()
                    return
                try:
                    if kind == "advance":
                        reply = proto.ok_frame(
                            "advance", msg_id,
                            self._advance(msg["branch"],
                                          msg.get("intervals", 1)))
                    else:
                        reply = proto.handle_inline(self.session, msg)
                        if kind == "fork":
                            self._event("fork", client=client.client_id,
                                        parent=msg["branch"],
                                        branch=reply["branch"])
                except SessionError as e:
                    # well-formed but invalid: envelope, keep serving
                    self._event("client_session_error",
                                client=client.client_id, message=str(e))
                    reply = proto.error_frame(msg_id, e)
                if msg.get("bin") and reply.get("kind") != "error":
                    # raw-array reply dialect, on request only: the
                    # client asked with "bin": true, so it can read
                    # RBW1 frames (requests themselves stay NDJSON)
                    tr.write_bin_frame(wfile, reply, client.counters)
                else:
                    tr.write_frame(wfile, reply, client.counters)
        except (ConnectionError, TimeoutError, OSError, BrokenPipeError):
            client.reason = client.reason or "eof"
        finally:
            client.reason = client.reason or "closed"
            for f in (wfile, rfile):
                try:
                    f.close()
                except OSError:
                    pass
            conn.close()
            client.open = False
            self._event("client_disconnect", client=client.client_id,
                        reason=client.reason)

    @staticmethod
    def _safe_write(wfile, client: _Client, frame: dict) -> None:
        """Best-effort write (the client may already be gone)."""
        try:
            tr.write_frame(wfile, frame, client.counters)
        except (ProtocolError, OSError):
            pass

    # -- coalescing executor -------------------------------------------------
    def _advance(self, branch: int, intervals: int) -> dict:
        """Enqueue an advance and block until the executor answers it.

        The shutdown check happens under the queue condition — the same
        lock the executor's exit check holds — so a request can never be
        enqueued after the executor decided to exit (which would strand
        this handler on ``done.wait`` forever). The executor-liveness
        poll is the backstop for the executor dying some way the
        dispatch guard did not foresee."""
        pending = _Pending(branch=int(branch), intervals=int(intervals))
        with self._queue_cv:
            if self._shutdown.is_set():
                raise SessionError("server is shutting down")
            self._queue.append(pending)
            self._queue_cv.notify()
        while not pending.done.wait(timeout=1.0):
            if not self._exec_thread.is_alive():
                raise SessionError("server executor is gone")
        if pending.error is not None:
            raise pending.error
        return pending.result

    def _executor_loop(self) -> None:
        while True:
            with self._queue_cv:
                while not self._queue and not self._shutdown.is_set():
                    self._queue_cv.wait(timeout=0.5)
                if self._shutdown.is_set() and not self._queue:
                    return
            # wait a beat so concurrent clients land in the same batch
            time.sleep(self.batch_window_s)
            with self._queue_cv:
                batch, self._queue = self._queue, []
            # an unknown branch id fails ONLY its own requester — it must
            # not poison the coalesced batch for well-behaved clients
            unknown, known_ids = self.session.unknown_branches(
                {p.branch for p in batch})
            known = []
            for p in batch:
                if p.branch in unknown:
                    p.error = SessionError(
                        f"unknown branch id {p.branch!r} (known: "
                        f"{known_ids})")
                    p.done.set()
                else:
                    known.append(p)
            merged: dict = {}
            for p in known:
                merged[p.branch] = merged.get(p.branch, 0) + p.intervals
            try:
                results = self.session.advance_many(merged) if merged \
                    else {}
                err = None
            except SessionError as e:   # defense in depth (races)
                results, err = {}, e
            except Exception as e:      # noqa: BLE001
                # a dispatch blowing up (e.g. a shape error that slipped
                # past fork-time validation) must fail THIS batch, not
                # kill the executor — a dead executor strands every
                # later advance on done.wait and breaks the "server
                # never dies on client behavior" guarantee
                results, err = {}, SessionError(f"advance failed: {e!r}")
                self.session.count_error()
                self._event("advance_batch_error", message=repr(e))
            self._event("advance_batch", branches=sorted(merged),
                        requests=len(batch),
                        coalesced=len(merged) > 1)
            for p in known:
                if err is not None or p.branch not in results:
                    p.error = err or SessionError(
                        f"unknown branch id {p.branch!r}")
                else:
                    p.result = results[p.branch]
                p.done.set()

    # -- lifecycle -----------------------------------------------------------
    def wait(self, timeout: Optional[float] = None) -> bool:
        """Block until a client requests shutdown (CI smoke mode)."""
        return self._shutdown.wait(timeout)

    def stats(self) -> dict:
        """Aggregated wire + session counters and the client ledger."""
        with self._clients_lock:
            wire = tr.WireCounters()
            for c in self.clients:
                for k, v in c.counters.as_dict().items():
                    setattr(wire, k, getattr(wire, k) + v)
            ledger = [{"client": c.client_id, "open": c.open,
                       "reason": c.reason} for c in self.clients]
        return {"address": self.address, "wire": wire.as_dict(),
                "session": dict(self.session.counters),
                "clients": ledger,
                "n_clients": len(ledger),
                "n_open": sum(1 for c in ledger if c["open"])}

    def close(self) -> dict:
        """Stop accepting, drain the executor, join every handler.

        Returns final ``stats()``. Asserts the ledger is fully closed —
        the zero-zombie guarantee the soak test leans on."""
        self._shutdown.set()
        with self._queue_cv:
            self._queue_cv.notify_all()
        try:
            self._listener.close()
        except OSError:
            pass
        self._accept_thread.join(timeout=5.0)
        self._exec_thread.join(timeout=5.0)
        with self._clients_lock:
            handlers = [c for c in self.clients if c.thread is not None]
        for c in handlers:
            try:
                c.sock.shutdown(socket.SHUT_RDWR)
            except OSError:
                pass
            c.sock.close()
            c.thread.join(timeout=5.0)
        stats = self.stats()
        self._event("server_stop", **{k: stats[k]
                                      for k in ("n_clients", "n_open")})
        if self.recorder is not None:
            self.recorder.finalize(counters={"wire": stats["wire"],
                                             "session": stats["session"]},
                                   clients=stats["clients"])
            self.recorder = None
        leaked = [c.client_id for c in self.clients
                  if c.thread is not None and c.thread.is_alive()]
        assert not leaked, f"client handler threads leaked: {leaked}"
        return stats

    def __enter__(self) -> "TwinServer":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
