"""Request protocol for the twin service (repro.serve.server).

Rides the PR 5 NDJSON wire unchanged: every frame is one JSON object per
line (``core.transport.write_frame``/``read_frame``, same
``MAX_FRAME_BYTES`` cap), every object carries ``version ==
WIRE_VERSION`` plus ``kind``. The serve dialect adds its own
``SERVE_VERSION`` to the greeting so protocol and service can version
independently.

Frame reference (full prose: docs/serving.md)
---------------------------------------------
==============  =========  ===============================================
kind            direction  payload
==============  =========  ===============================================
``hello``       twin→client  sent once on accept: ``serve_version``,
                             ``snapshot_version``, ``system`` (name,
                             n_nodes, dt, digest), ``jobs`` (n_jobs,
                             digest), window (``t0``/``t1``/
                             ``interval_steps``/``horizon_steps``)
``advance``     client→twin  ``branch``, ``intervals`` — queue the branch
                             for coalesced advancement
``fork``        client→twin  ``branch``, optional ``at_step`` +
                             ``delta`` (sparse Scenario knobs)
``snapshot``    client→twin  ``branch``, optional ``at_step`` — download
                             the checkpointed carry (serve.snapshot);
                             optional ``bin: true`` asks for the raw-array
                             dialect over an RBW1 binary frame
``fetch``       client→twin  ``branch``, optional ``start``/``stop`` —
                             scalar telemetry rows; ``bin: true`` returns
                             columnar arrays over a binary frame
``state``       client→twin  session + branch-tree summary
``shutdown``    client→twin  stop the whole server (CI smoke hook)
``bye``         client→twin  close this connection only
``*_ok``        twin→client  reply; echoes the request ``id`` when given
``error``       twin→client  ``error`` ("protocol" | "session"),
                             ``message``; echoes ``id``
==============  =========  ===============================================

Failure model — same classification as the scheduler wire: malformed
speech (bad JSON, wrong version, unknown kind, wrong field types) is a
``ProtocolError`` → the twin answers with an ``error`` envelope *and
closes that connection*; a semantically invalid request on a well-formed
frame (unknown branch id, fork point with no checkpoint, bad knob name)
is a ``SessionError`` → ``error`` envelope, connection stays up, session
state untouched. The server process never dies on either.
"""
from __future__ import annotations

from typing import Optional

from repro.core import transport as tr
from repro.core.external import WIRE_VERSION, ProtocolError
from repro.serve.session import SessionError, TwinSession
from repro.serve.snapshot import SNAPSHOT_VERSION

SERVE_VERSION = 1

# request kinds a client may send (everything else is broken speech)
REQUEST_KINDS = ("advance", "fork", "snapshot", "fetch", "state",
                 "shutdown", "bye")


def hello_frame(session: TwinSession, jobs=None) -> dict:
    """The twin's greeting, sent once per accepted connection."""
    sysc = session.system
    return {
        "version": WIRE_VERSION, "kind": "hello",
        "serve_version": SERVE_VERSION,
        "snapshot_version": SNAPSHOT_VERSION,
        # clients may request raw-array replies ("bin": true) on
        # snapshot/fetch; the greeting advertises the capability
        "caps": [tr.CAP_BINARY],
        "system": {"name": sysc.name, "n_nodes": int(sysc.n_nodes),
                   "dt": float(sysc.dt),
                   "n_halls": int(sysc.cooling.n_halls),
                   "digest": tr.system_digest(sysc)},
        "jobs": {"n_jobs": (len(jobs) if jobs is not None
                            else int(session.table.num_jobs)),
                 "digest": (tr.job_digest(jobs) if jobs is not None
                            else None)},
        "t0": session.t0, "t1": session.t1,
        "interval_steps": session.interval_steps,
        "horizon_steps": session.horizon_steps,
    }


def ok_frame(kind: str, msg_id, body: dict) -> dict:
    """Success reply for request ``kind`` (echoes the request id)."""
    out = {"version": WIRE_VERSION, "kind": f"{kind}_ok"}
    if msg_id is not None:
        out["id"] = msg_id
    out.update(body)
    return out


def error_frame(msg_id, exc: Exception) -> dict:
    """Error envelope; ``error`` field carries the failure class."""
    klass = "session" if isinstance(exc, SessionError) else "protocol"
    out = {"version": WIRE_VERSION, "kind": "error", "error": klass,
           "message": str(exc)}
    if msg_id is not None:
        out["id"] = msg_id
    return out


def _require_int(msg: dict, key: str, default=None,
                 minimum: Optional[int] = None):
    """Field must be an integer (or absent, when a default exists)."""
    if key not in msg:
        if default is not None or key in ("at_step", "start", "stop"):
            return default
        raise ProtocolError(f"{msg.get('kind')} request missing {key!r}")
    v = msg[key]
    if not isinstance(v, int) or isinstance(v, bool):
        raise ProtocolError(f"{key!r} must be an integer, got "
                            f"{type(v).__name__}")
    if minimum is not None and v < minimum:
        raise ProtocolError(f"{key!r} must be >= {minimum}, got {v}")
    return v


def validate_request(msg: dict) -> dict:
    """Well-formedness check; raises ``ProtocolError`` on broken speech.

    Returns the message unchanged so dispatchers can chain it. Semantic
    checks (does the branch exist?) belong to the session, not here.
    """
    if msg.get("version") != WIRE_VERSION:
        raise ProtocolError(f"wire version mismatch: client speaks "
                            f"{msg.get('version')!r}, twin speaks "
                            f"{WIRE_VERSION}")
    kind = msg.get("kind")
    if kind not in REQUEST_KINDS:
        raise ProtocolError(f"unknown request kind {kind!r} (valid: "
                            f"{', '.join(REQUEST_KINDS)})")
    if "id" in msg and not isinstance(msg["id"], (str, int)):
        raise ProtocolError("request id must be a string or integer")
    if kind == "advance":
        _require_int(msg, "branch", minimum=0)
        _require_int(msg, "intervals", default=1, minimum=0)
    elif kind in ("fork", "snapshot"):
        _require_int(msg, "branch", minimum=0)
        _require_int(msg, "at_step", minimum=0)
        if kind == "fork" and "delta" in msg and \
                not isinstance(msg["delta"], dict):
            raise ProtocolError(f"fork delta must be an object, got "
                                f"{type(msg['delta']).__name__}")
    elif kind == "fetch":
        _require_int(msg, "branch", minimum=0)
        _require_int(msg, "start", minimum=0)
        _require_int(msg, "stop", minimum=0)
    if kind in ("snapshot", "fetch") and "bin" in msg and \
            not isinstance(msg["bin"], bool):
        raise ProtocolError(f"'bin' must be a boolean, got "
                            f"{type(msg['bin']).__name__}")
    return msg


def handle_inline(session: TwinSession, msg: dict):
    """Dispatch every request kind except ``advance`` (which the server
    routes through its coalescing executor) and the connection-lifecycle
    kinds. Returns the reply frame; raises ``SessionError`` /
    ``ProtocolError`` for the server loop to envelope."""
    kind = msg["kind"]
    msg_id = msg.get("id")
    if kind == "fork":
        br = session.fork(msg["branch"], msg.get("delta"),
                          msg.get("at_step"))
        return ok_frame(kind, msg_id, {
            "branch": br.branch_id, "parent": br.parent,
            "step": br.step, "born_step": br.born_step,
            "delta": br.delta})
    if kind == "snapshot":
        return ok_frame(kind, msg_id,
                        session.snapshot(msg["branch"], msg.get("at_step"),
                                         binary=bool(msg.get("bin"))))
    if kind == "fetch":
        return ok_frame(kind, msg_id,
                        session.fetch(msg["branch"], msg.get("start"),
                                      msg.get("stop"),
                                      binary=bool(msg.get("bin"))))
    if kind == "state":
        return ok_frame(kind, msg_id, session.describe())
    raise ProtocolError(f"request kind {kind!r} has no inline handler")
