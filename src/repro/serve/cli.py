"""``simulate serve`` — run the twin as a persistent service.

    python -m repro.launch.simulate serve --listen unix:/tmp/twin.sock \\
        --system marconi100 --scale 64 --jobs 80 -t 2h --interval-steps 8

Prints one JSON line ``{"serving": "<bound address>", ...}`` to stdout
once the socket is listening (with ``--listen host:0`` the line carries
the kernel-assigned port), then blocks until a client sends ``shutdown``
or ``--max-seconds`` elapses. Talk to it with the stdlib client::

    python -m tools.twin_client --connect unix:/tmp/twin.sock \\
        --script "advance 0 3; fork 0 setpoint_delta_c=2.0; state; shutdown"

Protocol + failure model: docs/serving.md.
"""
from __future__ import annotations

import argparse
import json
import sys

from repro.core import types as T
from repro.datasets import loaders
from repro.serve.server import TwinServer
from repro.serve.session import TwinSession


def main(argv=None):
    ap = argparse.ArgumentParser(
        prog="simulate serve", description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("--listen", default="127.0.0.1:0",
                    help="bind address: unix:/path or host:port "
                         "(port 0 = kernel-assigned, reported on stdout)")
    ap.add_argument("--system", default="marconi100")
    ap.add_argument("--scale", type=int, default=0,
                    help="scale the system to N nodes (CPU-friendly)")
    ap.add_argument("--halls", type=int, default=0,
                    help="split the cooling plant into N halls")
    ap.add_argument("--jobs", type=int, default=1000)
    ap.add_argument("--days", type=float, default=None,
                    help="dataset horizon to generate (days)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("-ff", "--fastforward", default="0", type=str,
                    help="simulation start offset (s/m/h/d suffix)")
    ap.add_argument("-t", "--time", default="6h", type=str,
                    help="served horizon (simulated duration)")
    ap.add_argument("--interval-steps", type=int, default=8,
                    help="engine steps per interval: the checkpoint/"
                         "advance granularity of the session")
    ap.add_argument("--policy", default="fcfs",
                    help="root-branch scheduling policy")
    ap.add_argument("--backfill", default="none")
    ap.add_argument("--batch-window", type=float, default=0.01,
                    help="seconds the executor waits so concurrent "
                         "advances coalesce into one batched sweep")
    ap.add_argument("--client-timeout", type=float, default=60.0,
                    help="per-connection read timeout (s); a hung "
                         "client is dropped after this long")
    ap.add_argument("--obs-dir", default=None, metavar="DIR",
                    help="write a per-session run manifest + NDJSON "
                         "event log under DIR (docs/observability.md)")
    ap.add_argument("--max-seconds", type=float, default=None,
                    help="exit after this long even without a shutdown "
                         "request (CI guard)")
    args = ap.parse_args(argv)

    from repro.launch.simulate import _parse_time, build_system
    sys_ = build_system(args.system, args.scale, args.halls)
    if args.interval_steps < 1:
        ap.error(f"--interval-steps must be >= 1, got "
                 f"{args.interval_steps}")
    t0 = _parse_time(args.fastforward)
    # advances land on interval boundaries, so the session rejects a
    # horizon with a trailing partial interval — round the requested
    # duration down to a whole number of intervals (the effective
    # horizon_steps is reported in the startup line and every hello)
    steps = int(round(_parse_time(args.time) / sys_.dt))
    steps -= steps % args.interval_steps
    if steps < args.interval_steps:
        ap.error(f"-t {args.time} is shorter than one interval "
                 f"({args.interval_steps} steps x {sys_.dt:g}s)")
    t1 = t0 + steps * float(sys_.dt)
    days = args.days or max((t1 / 86400.0) * 1.25, 0.5)
    js = loaders.load(args.system, n_jobs=args.jobs, days=days,
                      seed=args.seed)
    js.assign_prepop_placement(t0, sys_.n_nodes)
    table = js.to_table()
    scen = T.Scenario.make(args.policy, args.backfill)

    session = TwinSession(sys_, table, scen, t0, t1,
                          interval_steps=args.interval_steps)
    server = TwinServer(session, args.listen, jobs=js,
                        batch_window_s=args.batch_window,
                        obs_dir=args.obs_dir,
                        client_timeout_s=args.client_timeout)
    print(json.dumps({"serving": server.address,
                      "system": sys_.name, "n_nodes": int(sys_.n_nodes),
                      "horizon_steps": session.horizon_steps,
                      "interval_steps": session.interval_steps}),
          flush=True)
    try:
        server.wait(args.max_seconds)
    except KeyboardInterrupt:
        pass
    stats = server.close()
    print(json.dumps({"served": stats["n_clients"],
                      "wire": stats["wire"],
                      "session": stats["session"]}), flush=True)
    return 0


if __name__ == "__main__":
    sys.exit(main())
