"""Branch/session manager for the persistent twin (repro.serve).

A ``TwinSession`` owns one (system, job table, horizon) and a tree of
**branches**. Branch 0 is the root trajectory; any branch can be forked
at any of its interval checkpoints into a child with a modified
``Scenario`` — the child inherits the parent's scan carry at the fork
point, so its prefix costs nothing to "re-simulate" (it never is).

Time is discrete: the horizon is split into *intervals* of
``interval_steps`` engine steps, and every advance lands on an interval
boundary, where the full carry is checkpointed. This is what makes the
service deterministic and the parity oracle exact — a branch's state at
step k does not depend on the segmentation that produced it
(``engine.simulate_segment`` chains are bit-identical to one scan;
tests/test_serve_checkpoint.py).

Coalescing: ``advance_many`` moves any set of branches forward
tick-by-tick, and every tick dispatches ALL branches that still need
work as ONE ``engine.simulate_segment_sweep`` batch — the batched scan
is bitwise identical to running them serially (vmap over carries and
scenarios; proven by the soak test's decision-identity assertion), so
coalescing concurrent client what-ifs is pure throughput, never a
semantics change. Branches at different absolute steps batch fine:
grid/weather inputs are gathered at each carry's own ``step`` cursor
inside the scan.

Thread-safety: one re-entrant lock around all mutating entry points.
The server (repro.serve.server) funnels advances through a single
executor thread anyway; the lock makes direct library use safe too.
"""
from __future__ import annotations

import threading
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import numpy as np

from repro.core import engine
from repro.core import types as T
from repro.obs import sink as obs_sink
from repro.serve import snapshot as snap


class SessionError(RuntimeError):
    """Semantically invalid request (unknown branch, bad fork point, ...).

    Distinct from ``transport.ProtocolError`` (malformed speech): a
    SessionError is answered with an error envelope and the connection
    stays up; the session itself is never corrupted by one.
    """


@dataclass
class Branch:
    """One trajectory in the fork tree."""
    branch_id: int
    parent: Optional[int]          # parent branch id (None for the root)
    scenario: T.Scenario           # knobs this branch simulates under
    delta: dict                    # sparse knob delta vs the parent
    carry: T.SimState              # scan carry at ``step``
    step: int                      # absolute engine step of ``carry``
    born_step: int                 # fork point (0 for the root)
    # carry at every interval boundary visited since birth (includes the
    # birth checkpoint) — any of these is a legal fork/snapshot point.
    # Stored as HOST numpy pytrees: a long-lived session accumulates one
    # per tick per branch, and only the live ``carry`` needs to stay on
    # device (forking moves the chosen checkpoint back; the numpy<->jnp
    # roundtrip is byte-exact, so parity is unaffected)
    checkpoints: Dict[int, T.SimState] = field(default_factory=dict)
    # StepRecord history per advanced segment (host numpy, in step order)
    history: List[T.StepRecord] = field(default_factory=list)

    def __post_init__(self):
        if self.step not in self.checkpoints:
            self.checkpoints[self.step] = _to_host(self.carry)


class TwinSession:
    """A persistent simulation session: one system, a tree of branches."""

    def __init__(self, system, table, scen: T.Scenario, t0: float,
                 t1: float, interval_steps: int,
                 signals=None, weather=None, num_accounts: int = 64,
                 events=None):
        if interval_steps < 1:
            raise ValueError(f"interval_steps must be >= 1, got "
                             f"{interval_steps}")
        self.system = system
        self.table = table
        self.t0 = float(t0)
        self.t1 = float(t1)
        self.interval_steps = int(interval_steps)
        self.horizon_steps = int(round((t1 - t0) / system.dt))
        if self.horizon_steps % self.interval_steps:
            # advances always land on interval boundaries, so a trailing
            # partial interval could never be simulated — reject loudly
            # instead of silently stopping short of t1
            raise ValueError(
                f"horizon ({self.horizon_steps} steps) must be a "
                f"multiple of interval_steps ({self.interval_steps}): "
                f"the {self.horizon_steps % self.interval_steps}-step "
                f"tail would be unreachable")
        self.signals = signals
        self.weather = weather
        # static EventConfig (repro.events) shared by every branch: the
        # failure *knobs* (seed/rates/DR) are per-branch Scenario leaves,
        # so a fork injects failures by delta alone — a session created
        # with events=EventConfig() and zero-rate knobs stays nominal
        self.events = events
        self._lock = threading.RLock()
        self.counters = {"advances": 0, "segments": 0, "forks": 0,
                         "snapshots": 0, "fetches": 0, "errors": 0,
                         "coalesced_batches": 0, "batched_branches": 0}
        root_carry = engine.init_state(system, table, t0, t1,
                                       num_accounts=num_accounts,
                                       events=events)
        # a host copy of the root carry is the decode template for
        # snapshots of any branch (same (system, table) lineage => same
        # pytree shapes). Host copy, not the live carry: branch 0's
        # first advance *donates* its carry buffers to the scan
        # (engine.DONATE_CARRIES) and the template must outlive that.
        self.carry_template = _to_host(root_carry)
        self._next_id = 1
        self.branches: Dict[int, Branch] = {
            0: Branch(branch_id=0, parent=None, scenario=scen, delta={},
                      carry=root_carry, step=0, born_step=0)}

    # -- lookup --------------------------------------------------------------
    def _branch(self, branch_id) -> Branch:
        try:
            br = self.branches[int(branch_id)]
        except (KeyError, TypeError, ValueError):
            self.counters["errors"] += 1
            raise SessionError(
                f"unknown branch id {branch_id!r} (known: "
                f"{sorted(self.branches)})") from None
        return br

    def unknown_branches(self, branch_ids):
        """Partition ids into (unknown set, known-ids list), atomically.

        The server's coalescing executor screens each batch with this so
        one client's stale id fails only its own request and never
        poisons the shared sweep — and does so under the session lock,
        honoring the one-lock contract while handler threads fork
        concurrently. Each unknown id counts as one error.
        """
        with self._lock:
            unknown = {b for b in branch_ids if b not in self.branches}
            self.counters["errors"] += len(unknown)
            return unknown, sorted(self.branches)

    def count_error(self) -> None:
        """Count one server-side failure under the session lock."""
        with self._lock:
            self.counters["errors"] += 1

    # -- advance (the hot path) ----------------------------------------------
    def advance_many(self, requests: Dict[int, int]) -> Dict[int, dict]:
        """Advance several branches, coalescing per interval tick.

        Args:
          requests: branch id -> number of intervals to advance. Branches
            are clamped at the horizon (advancing a finished branch is a
            no-op, not an error — clients polling "advance 1" race the
            horizon benignly).
        Returns:
          branch id -> {"step", "t", "advanced_steps"} after the advance.
        """
        with self._lock:
            remaining = {self._branch(b).branch_id: int(n)
                         for b, n in requests.items()}
            if any(n < 0 for n in remaining.values()):
                raise SessionError("advance count must be >= 0")
            advanced = {b: 0 for b in remaining}
            while True:
                live = [b for b, n in remaining.items() if n > 0 and
                        self.branches[b].step + self.interval_steps
                        <= self.horizon_steps]
                if not live:
                    break
                self._tick(live)
                for b in live:
                    remaining[b] -= 1
                    advanced[b] += self.interval_steps
            self.counters["advances"] += 1
            return {b: {"step": self.branches[b].step,
                        "t": self.t0 + self.branches[b].step
                        * float(self.system.dt),
                        "advanced_steps": advanced[b]}
                    for b in remaining}

    def _tick(self, branch_ids: List[int]) -> None:
        """One interval for every listed branch — one dispatch total."""
        n = self.interval_steps
        if len(branch_ids) == 1:
            br = self.branches[branch_ids[0]]
            carry, hist = engine.simulate_segment(
                self.system, self.table, br.carry, br.scenario, n,
                self.signals, self.weather, self.events)
            self._commit(br, carry, hist)
        else:
            brs = [self.branches[b] for b in branch_ids]
            carries, hists = engine.simulate_segment_sweep(
                self.system, self.table, [b.carry for b in brs],
                [b.scenario for b in brs], n, self.signals, self.weather,
                self.events)
            self.counters["coalesced_batches"] += 1
            self.counters["batched_branches"] += len(brs)
            for i, br in enumerate(brs):
                self._commit(br, _tree_index(carries, i),
                             _tree_index(hists, i))
        self.counters["segments"] += len(branch_ids)

    def _commit(self, br: Branch, carry, hist) -> None:
        br.carry = carry
        br.step += self.interval_steps
        # checkpoints and history live on host: only the live carry is
        # hot, and a session holds one checkpoint per tick per branch
        br.checkpoints[br.step] = _to_host(carry)
        br.history.append(_to_host(hist))

    # -- fork ----------------------------------------------------------------
    def fork(self, parent_id, delta: Optional[dict] = None,
             at_step: Optional[int] = None) -> Branch:
        """Branch ``parent_id`` at one of its checkpoints.

        Args:
          parent_id: branch to fork from.
          delta: sparse Scenario knob delta (``{}``/None = neutral fork,
            bit-identical to the parent from the fork point on).
          at_step: fork point; must be an interval checkpoint the parent
            has visited (default: its current step).
        Returns:
          the new ``Branch`` (its id is ``branch_id``).
        """
        with self._lock:
            parent = self._branch(parent_id)
            step = parent.step if at_step is None else int(at_step)
            if step not in parent.checkpoints:
                self.counters["errors"] += 1
                raise SessionError(
                    f"branch {parent.branch_id} has no checkpoint at step "
                    f"{step} (available: {sorted(parent.checkpoints)})")
            try:
                scen = snap.apply_scenario_delta(parent.scenario,
                                                 delta or {})
            except snap.SnapshotError as e:
                self.counters["errors"] += 1
                raise SessionError(str(e)) from e
            child = Branch(branch_id=self._next_id, parent=parent.branch_id,
                           scenario=scen, delta=dict(delta or {}),
                           carry=_to_device(parent.checkpoints[step]),
                           step=step, born_step=step,
                           checkpoints={step: parent.checkpoints[step]})
            self._next_id += 1
            self.branches[child.branch_id] = child
            self.counters["forks"] += 1
            return child

    # -- snapshot / fetch / state -------------------------------------------
    def snapshot(self, branch_id, at_step: Optional[int] = None,
                 binary: bool = False) -> dict:
        """Encode a branch checkpoint for the wire (see serve.snapshot).

        ``binary=True`` produces the raw-array snapshot dialect (leaves
        are host ndarrays, shipped as RBW1 binary frames by the server);
        the reply's ``digest`` is then the dialect-independent
        ``carry_digest`` instead of the canonical-JSON digest."""
        with self._lock:
            br = self._branch(branch_id)
            step = br.step if at_step is None else int(at_step)
            if step not in br.checkpoints:
                self.counters["errors"] += 1
                raise SessionError(
                    f"branch {br.branch_id} has no checkpoint at step "
                    f"{step} (available: {sorted(br.checkpoints)})")
            payload = snap.encode_carry(br.checkpoints[step],
                                        binary=binary)
            self.counters["snapshots"] += 1
            out = {"branch": br.branch_id, "step": step,
                   "snapshot": payload,
                   "raw_digest": snap.carry_digest(payload)}
            if not binary:
                out["digest"] = snap.snapshot_digest(payload)
            return out

    def fetch(self, branch_id, start: Optional[int] = None,
              stop: Optional[int] = None, binary: bool = False) -> dict:
        """Scalar telemetry rows of a branch (since its fork point).

        ``start``/``stop`` are absolute step bounds (default: everything
        the branch has simulated itself — a child's history starts at its
        ``born_step``; the prefix lives on its ancestors).

        ``binary=True`` returns the same telemetry *columnar* — one
        float64 array per field under ``"cols"`` instead of per-row
        dicts — which the binary frame dialect ships as raw bytes
        (per-row JSON objects at Frontier scale are mostly key text).
        """
        with self._lock:
            br = self._branch(branch_id)
            lo = br.born_step if start is None else int(start)
            hi = br.step if stop is None else int(stop)
            lo = max(lo, br.born_step)
            hi = min(hi, br.step)
            fields = ["step", "t", *obs_sink.SCALAR_FIELDS]
            rows, cols = [], None
            if br.history and hi > lo:
                cat = {k: np.concatenate(
                    [np.asarray(getattr(h, k), np.float64)
                     for h in br.history])
                    for k in ("t",) + obs_sink.SCALAR_FIELDS}
                a, b = lo - br.born_step, hi - br.born_step
                if binary:
                    cols = {"step": np.arange(lo, hi, dtype=np.int64)}
                    cols.update({k: v[a:b].copy() for k, v in cat.items()})
                else:
                    for i in range(a, b):
                        row = {"step": br.born_step + i}
                        row.update({k: float(v[i])
                                    for k, v in cat.items()})
                        rows.append(row)
            elif binary:
                cols = {"step": np.zeros((0,), np.int64),
                        **{k: np.zeros((0,), np.float64)
                           for k in ("t",) + obs_sink.SCALAR_FIELDS}}
            self.counters["fetches"] += 1
            out = {"branch": br.branch_id, "start": lo, "stop": hi,
                   "fields": fields}
            if binary:
                out["cols"] = cols
            else:
                out["rows"] = rows
            return out

    def describe(self) -> dict:
        """Session + branch-tree summary (the ``state`` reply body)."""
        with self._lock:
            return {
                "system": self.system.name,
                "n_nodes": int(self.system.n_nodes),
                "dt": float(self.system.dt),
                "t0": self.t0, "t1": self.t1,
                "interval_steps": self.interval_steps,
                "horizon_steps": self.horizon_steps,
                "branches": [
                    {"branch": b.branch_id, "parent": b.parent,
                     "step": b.step, "born_step": b.born_step,
                     "delta": b.delta,
                     "checkpoints": sorted(b.checkpoints)}
                    for b in sorted(self.branches.values(),
                                    key=lambda b: b.branch_id)],
                "counters": dict(self.counters),
            }


def _tree_index(tree, i: int):
    """Row ``i`` of every leaf of a stacked pytree."""
    import jax
    return jax.tree_util.tree_map(lambda x: x[i], tree)


def _to_host(tree):
    """Move a pytree (StepRecord history, checkpoint carry) to host
    numpy — frees device memory for long-lived sessions; fetch slices
    host history without device syncs."""
    import jax
    return jax.tree_util.tree_map(lambda x: np.asarray(x), tree)


def _to_device(tree):
    """Put a host checkpoint back on device (byte-exact inverse of
    ``_to_host``; forking resumes from the result bit-identically)."""
    import jax
    import jax.numpy as jnp
    return jax.tree_util.tree_map(jnp.asarray, tree)
