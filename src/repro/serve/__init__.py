"""Twin-as-a-service: persistent simulation sessions with snapshot/fork
what-if branching (docs/serving.md).

Layers, bottom up:

* ``snapshot`` — byte-faithful codec for the scan carry (checkpoint and
  download format) + the Scenario delta wire form;
* ``session``  — the branch manager: interval checkpoints, forks from
  any checkpoint, per-tick coalescing of concurrent advances into one
  batched sweep;
* ``protocol`` — the NDJSON request dialect over the PR 5 transport;
* ``server``   — sockets, threads, the coalescing executor, obs;
* ``cli``      — ``python -m repro.launch.simulate serve ...``.

The stdlib-only client lives outside the package on purpose
(``tools/twin_client.py``): anything that reads lines of JSON can talk
to the twin, no repro import required.
"""
from repro.serve.session import Branch, SessionError, TwinSession
from repro.serve.server import TwinServer
from repro.serve.snapshot import (SNAPSHOT_VERSION, SnapshotError,
                                  apply_scenario_delta, decode_carry,
                                  encode_carry, encode_scenario,
                                  snapshot_digest)
from repro.serve.protocol import SERVE_VERSION

__all__ = ["Branch", "SessionError", "TwinSession", "TwinServer",
           "SNAPSHOT_VERSION", "SnapshotError", "apply_scenario_delta",
           "decode_carry", "encode_carry", "encode_scenario",
           "snapshot_digest", "SERVE_VERSION"]
